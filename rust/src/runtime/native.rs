//! Native plaintext quantized BERT — the Rust twin of
//! `python/compile/kernels/ref.py` + `model.py` (bit-exact).
//!
//! Used as (a) the reference the MPC pipeline is validated against,
//! (b) the non-private baseline in benches, and (c) the calibration
//! engine for synthetic BERT-base weights.

use std::collections::HashMap;

use crate::model::config::BertConfig;
use crate::model::weights::{Tensor, Weights};
use crate::protocols::tables;

const MASK16: u64 = 0xFFFF;

/// Decode a 4-bit ring value to its signed representative.
#[inline]
pub fn signed4(v: u64) -> i64 {
    (((v & 0xF) ^ 0x8) as i64) - 0x8
}

/// The pipeline's `trc(·, 4)` on a 16-bit accumulator (top 4 bits, signed).
#[inline]
pub fn trc16_to4(acc: i64) -> i64 {
    signed4(((acc as u64) & MASK16) >> 12)
}

/// Binary-weight FC: `trc16_to4( x [rows,k] · (scale·W [m,k])ᵀ )`.
pub fn fc_quant(x: &[i64], rows: usize, k: usize, w: &Tensor, scale: i64) -> Vec<i64> {
    let m = w.shape[0];
    debug_assert_eq!(w.shape[1], k);
    let mut out = vec![0i64; rows * m];
    for r in 0..rows {
        for o in 0..m {
            let mut acc = 0i64;
            let wr = &w.data[o * k..(o + 1) * k];
            let xr = &x[r * k..(r + 1) * k];
            for j in 0..k {
                acc += xr[j] * wr[j];
            }
            out[r * m + o] = trc16_to4(acc * scale);
        }
    }
    out
}

/// Activation-activation quantized matmul: `a [m,k] · b [k,n]`, rescale.
pub fn matmul_quant(a: &[i64], m: usize, k: usize, b: &[i64], n: usize, scale: i64) -> Vec<i64> {
    let mut out = vec![0i64; m * n];
    for r in 0..m {
        for c in 0..n {
            let mut acc = 0i64;
            for j in 0..k {
                acc += a[r * k + j] * b[j * n + c];
            }
            out[r * n + c] = trc16_to4(acc * scale);
        }
    }
    out
}

/// Quantized softmax over each length-`n` row (ref.softmax_quant).
pub fn softmax_quant(x: &[i64], rows: usize, n: usize, sx: f64) -> Vec<i64> {
    let te = tables::exp_table(sx);
    let td = tables::div_table();
    let mut out = vec![0i64; rows * n];
    for r in 0..rows {
        let row = &x[r * n..(r + 1) * n];
        let xo = *row.iter().max().unwrap();
        let e: Vec<u64> = row
            .iter()
            .map(|&v| te.entries[((v - xo).rem_euclid(16)) as usize])
            .collect();
        let big: u64 = e.iter().fold(0u64, |a, &b| (a + b) & 0xFF);
        let den = (big >> 4) & 0xF;
        for (j, &ej) in e.iter().enumerate() {
            out[r * n + j] = td.entries[((ej & 0xF) * 16 + den) as usize] as i64;
        }
    }
    out
}

/// Elementwise ReLU on quantized values (ref.relu_quant).
pub fn relu_quant(x: &[i64]) -> Vec<i64> {
    x.iter().map(|&v| v.max(0)).collect()
}

/// Quantized LayerNorm over each length-`n` row (ref.layernorm_quant).
#[allow(clippy::too_many_arguments)]
pub fn layernorm_quant(
    r16: &[i64],
    rows: usize,
    n: usize,
    s_v: f64,
    eps: f64,
    gamma: &Tensor,
    gamma_scale: i64,
    beta: &Tensor,
) -> Vec<i64> {
    let c = (4096 / n) as i64;
    let t = tables::ln_div_table(s_v, eps);
    let mut out = vec![0i64; rows * n];
    for row in 0..rows {
        let x = &r16[row * n..(row + 1) * n];
        let sum: i64 = x.iter().sum();
        let m16 = ((c * sum) as u64) & MASK16;
        let mu = signed4(m16 >> 12);
        let var: i64 = x.iter().map(|&v| (v - mu) * (v - mu)).sum();
        let v16 = ((var * c) as u64) & MASK16;
        let v4 = (v16 >> 12) & 0xF;
        for j in 0..n {
            let a6 = ((x[j] - mu) as u64) & 0x3F;
            let u = signed4(t.entries[(a6 * 16 + v4) as usize]);
            let acc = u * gamma.data[j] * gamma_scale;
            let g = trc16_to4(acc);
            out[row * n + j] = signed4(((g + beta.data[j]) as u64) & 0xF);
        }
    }
    out
}

/// One encoder layer (mirrors python `encoder_layer`).
pub fn encoder_layer(cfg: &BertConfig, w: &Weights, li: usize, h: &[i64]) -> Vec<i64> {
    let (s, d, dh) = (cfg.seq_len, cfg.d_model, cfg.d_head());
    let p = |name: &str| format!("layer{li}.{name}");
    let sc = |name: &str| w.scale(&format!("layer{li}.s_{name}"));

    let q = fc_quant(h, s, d, w.tensor(&p("wq")), sc("qkv"));
    let k = fc_quant(h, s, d, w.tensor(&p("wk")), sc("qkv"));
    let v = fc_quant(h, s, d, w.tensor(&p("wv")), sc("qkv"));

    let mut ctxcat = vec![0i64; s * d];
    for hd in 0..cfg.n_heads {
        let slice = |t: &[i64]| -> Vec<i64> {
            let mut out = vec![0i64; s * dh];
            for r in 0..s {
                out[r * dh..(r + 1) * dh]
                    .copy_from_slice(&t[r * d + hd * dh..r * d + (hd + 1) * dh]);
            }
            out
        };
        let (qs, ks, vs) = (slice(&q), slice(&k), slice(&v));
        // scores = qs [s,dh] @ ks^T [dh,s]
        let kst: Vec<i64> = {
            let mut t = vec![0i64; dh * s];
            for r in 0..s {
                for c in 0..dh {
                    t[c * s + r] = ks[r * dh + c];
                }
            }
            t
        };
        let scores = matmul_quant(&qs, s, dh, &kst, s, sc("att"));
        let attn = softmax_quant(&scores, s, s, cfg.sm_sx);
        let ctx = matmul_quant(&attn, s, s, &vs, dh, sc("av"));
        for r in 0..s {
            ctxcat[r * d + hd * dh..r * d + (hd + 1) * dh]
                .copy_from_slice(&ctx[r * dh..(r + 1) * dh]);
        }
    }
    let o = fc_quant(&ctxcat, s, d, w.tensor(&p("wo")), sc("o"));
    let res: Vec<i64> = h.iter().zip(&o).map(|(&a, &b)| a + b).collect();
    let h1 = layernorm_quant(&res, s, d, cfg.ln_sv, cfg.ln_eps,
                             w.tensor(&p("ln1_g")), sc("g1"), w.tensor(&p("ln1_b")));
    let u = fc_quant(&h1, s, d, w.tensor(&p("w1")), sc("f1"));
    let u = relu_quant(&u);
    let f = fc_quant(&u, s, cfg.d_ff, w.tensor(&p("w2")), sc("f2"));
    let res2: Vec<i64> = h1.iter().zip(&f).map(|(&a, &b)| a + b).collect();
    layernorm_quant(&res2, s, d, cfg.ln_sv, cfg.ln_eps,
                    w.tensor(&p("ln2_g")), sc("g2"), w.tensor(&p("ln2_b")))
}

/// Full forward: returns (logits over the CLS token, final hidden).
pub fn forward(cfg: &BertConfig, w: &Weights, x4: &[i64]) -> (Vec<i64>, Vec<i64>) {
    let mut h = x4.to_vec();
    for li in 0..cfg.n_layers {
        h = encoder_layer(cfg, w, li, &h);
    }
    let cls = w.tensor("cls.w");
    let d = cfg.d_model;
    let logits = (0..cfg.n_classes)
        .map(|c| {
            let mut acc = 0i64;
            for j in 0..d {
                acc += h[j] * cls.data[c * d + j] * cfg.scale_cls;
            }
            // signed 16-bit interpretation of the ring value
            let v = (acc as u64) & MASK16;
            if v >= 0x8000 { v as i64 - 0x10000 } else { v as i64 }
        })
        .collect();
    (logits, h)
}

/// Scale calibration (python `calibrate`): run the forward once, choosing
/// each op's `floor(2^12·s_w·s_x/s_y)` so outputs span the 4-bit range.
pub fn calibrate(cfg: &BertConfig, w: &mut Weights, x4: &[i64]) {
    let (s, d, dh) = (cfg.seq_len, cfg.d_model, cfg.d_head());
    let pick = |accs: &[i64]| -> i64 {
        let mut mags: Vec<i64> = accs.iter().map(|&a| a.abs()).collect();
        mags.sort_unstable();
        let p99 = mags[((mags.len() - 1) as f64 * 0.99) as usize].max(1);
        ((7.0 * 4096.0 / p99 as f64).round() as i64).clamp(1, 4095)
    };
    let raw_fc = |x: &[i64], rows: usize, k: usize, t: &Tensor| -> Vec<i64> {
        let m = t.shape[0];
        let mut out = vec![0i64; rows * m];
        for r in 0..rows {
            for o in 0..m {
                let mut acc = 0i64;
                for j in 0..k {
                    acc += x[r * k + j] * t.data[o * k + j];
                }
                out[r * m + o] = acc;
            }
        }
        out
    };

    let mut scales: HashMap<String, i64> = HashMap::new();
    let mut h = x4.to_vec();
    for li in 0..cfg.n_layers {
        let p = |n: &str| format!("layer{li}.{n}");
        // QKV
        let mut acc = raw_fc(&h, s, d, w.tensor(&p("wq")));
        acc.extend(raw_fc(&h, s, d, w.tensor(&p("wk"))));
        acc.extend(raw_fc(&h, s, d, w.tensor(&p("wv"))));
        scales.insert(p("s_qkv"), pick(&acc));
        let sqkv = scales[&p("s_qkv")];
        let q = fc_quant(&h, s, d, w.tensor(&p("wq")), sqkv);
        let k = fc_quant(&h, s, d, w.tensor(&p("wk")), sqkv);
        let v = fc_quant(&h, s, d, w.tensor(&p("wv")), sqkv);
        // attention scores
        let slice = |t: &[i64], hd: usize| -> Vec<i64> {
            let mut out = vec![0i64; s * dh];
            for r in 0..s {
                out[r * dh..(r + 1) * dh]
                    .copy_from_slice(&t[r * d + hd * dh..r * d + (hd + 1) * dh]);
            }
            out
        };
        let mut acc = Vec::new();
        for hd in 0..cfg.n_heads {
            let (qs, ks) = (slice(&q, hd), slice(&k, hd));
            for r in 0..s {
                for c in 0..s {
                    let mut a = 0i64;
                    for j in 0..dh {
                        a += qs[r * dh + j] * ks[c * dh + j];
                    }
                    acc.push(a);
                }
            }
        }
        scales.insert(p("s_att"), pick(&acc));
        let satt = scales[&p("s_att")];
        // attn @ V
        let mut acc_av = Vec::new();
        let mut ctxcat = vec![0i64; s * d];
        let mut attns = Vec::new();
        for hd in 0..cfg.n_heads {
            let (qs, ks) = (slice(&q, hd), slice(&k, hd));
            let kst: Vec<i64> = {
                let mut t = vec![0i64; dh * s];
                for r in 0..s {
                    for c in 0..dh {
                        t[c * s + r] = ks[r * dh + c];
                    }
                }
                t
            };
            let scores = matmul_quant(&qs, s, dh, &kst, s, satt);
            let attn = softmax_quant(&scores, s, s, cfg.sm_sx);
            let vs = slice(&v, hd);
            for r in 0..s {
                for c in 0..dh {
                    let mut a = 0i64;
                    for j in 0..s {
                        a += attn[r * s + j] * vs[j * dh + c];
                    }
                    acc_av.push(a);
                }
            }
            attns.push((attn, vs));
        }
        scales.insert(p("s_av"), pick(&acc_av));
        let sav = scales[&p("s_av")];
        for (hd, (attn, vs)) in attns.iter().enumerate() {
            let ctx = matmul_quant(attn, s, s, vs, dh, sav);
            for r in 0..s {
                ctxcat[r * d + hd * dh..r * d + (hd + 1) * dh]
                    .copy_from_slice(&ctx[r * dh..(r + 1) * dh]);
            }
        }
        // Wo
        let acc = raw_fc(&ctxcat, s, d, w.tensor(&p("wo")));
        scales.insert(p("s_o"), pick(&acc));
        let o = fc_quant(&ctxcat, s, d, w.tensor(&p("wo")), scales[&p("s_o")]);
        let res: Vec<i64> = h.iter().zip(&o).map(|(&a, &b)| a + b).collect();
        scales.insert(p("s_g1"), 2048);
        let h1 = layernorm_quant(&res, s, d, cfg.ln_sv, cfg.ln_eps,
                                 w.tensor(&p("ln1_g")), 2048, w.tensor(&p("ln1_b")));
        // FFN
        let acc = raw_fc(&h1, s, d, w.tensor(&p("w1")));
        scales.insert(p("s_f1"), pick(&acc));
        let u = relu_quant(&fc_quant(&h1, s, d, w.tensor(&p("w1")), scales[&p("s_f1")]));
        let acc = raw_fc(&u, s, cfg.d_ff, w.tensor(&p("w2")));
        scales.insert(p("s_f2"), pick(&acc));
        let f = fc_quant(&u, s, cfg.d_ff, w.tensor(&p("w2")), scales[&p("s_f2")]);
        let res2: Vec<i64> = h1.iter().zip(&f).map(|(&a, &b)| a + b).collect();
        scales.insert(p("s_g2"), 2048);
        h = layernorm_quant(&res2, s, d, cfg.ln_sv, cfg.ln_eps,
                            w.tensor(&p("ln2_g")), 2048, w.tensor(&p("ln2_b")));
    }
    w.scales = scales;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::{synth_input, Weights};

    fn tiny_calibrated() -> (BertConfig, Weights, Vec<i64>) {
        let cfg = BertConfig::tiny();
        let mut w = Weights::synth(cfg, 42);
        let xc = synth_input(&cfg, 5);
        calibrate(&cfg, &mut w, &xc);
        let x = synth_input(&cfg, 11);
        (cfg, w, x)
    }

    #[test]
    fn forward_shapes_and_ranges() {
        let (cfg, w, x) = tiny_calibrated();
        let (logits, h) = forward(&cfg, &w, &x);
        assert_eq!(logits.len(), cfg.n_classes);
        assert_eq!(h.len(), cfg.seq_len * cfg.d_model);
        assert!(h.iter().all(|&v| (-8..8).contains(&v)));
    }

    #[test]
    fn forward_depends_on_input() {
        let (cfg, w, x) = tiny_calibrated();
        let (_, h1) = forward(&cfg, &w, &x);
        let x2 = synth_input(&cfg, 99);
        let (_, h2) = forward(&cfg, &w, &x2);
        let diff = h1.iter().zip(&h2).filter(|(a, b)| a != b).count();
        assert!(diff * 5 > h1.len(), "only {diff}/{} differ", h1.len());
    }

    #[test]
    fn calibration_keeps_signal_alive() {
        let (cfg, w, x) = tiny_calibrated();
        let (_, h) = forward(&cfg, &w, &x);
        let mean: f64 = h.iter().map(|&v| v as f64).sum::<f64>() / h.len() as f64;
        let var: f64 =
            h.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / h.len() as f64;
        assert!(var.sqrt() > 0.5, "hidden std {}", var.sqrt());
    }

    #[test]
    fn softmax_rows_sum_near_16() {
        // quantized softmax outputs roughly preserve the normalization
        let x = vec![3i64, -5, 7, 0, -8, 2, 1, -1];
        let out = softmax_quant(&x, 1, 8, 0.5);
        let sum: i64 = out.iter().sum();
        assert!((8..=24).contains(&sum), "{out:?}");
    }
}
