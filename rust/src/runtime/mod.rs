//! Execution runtimes: the native plaintext oracle and the PJRT loader
//! for the JAX/Pallas AOT artifacts.

pub mod native;
pub mod xla;
