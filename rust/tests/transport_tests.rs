//! Cross-backend transport parity and wire-protocol integration tests
//! (DESIGN.md §Transport backends).
//!
//! The load-bearing claim of the pluggable transport layer is that the
//! backend is *unobservable* above `Net`: the same protocol run over the
//! in-process mesh and over loopback TCP must produce bit-identical
//! logits AND an identical meter (per-link bytes/messages, per-party
//! rounds, per phase) — otherwise LAN/WAN numbers would stop being
//! comparable across deployments.

use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use ppq_bert::bench_harness::{prepared_inputs, prepared_model};
use ppq_bert::coordinator::remote::{run_party, session_id, PartyOpts, RemoteClient};
use ppq_bert::coordinator::{Coordinator, ServerConfig};
use ppq_bert::model::config::{BertConfig, TaskKind};
use ppq_bert::model::secure::{secure_infer_batch, GraphSpec};
use ppq_bert::party::{PartyCtx, SessionCfg, P0, P1};
use ppq_bert::transport::wire::{self, Accepted, PartyHello, Tag};
use ppq_bert::transport::{build_mesh, loopback_mesh, Metrics, MetricsSnapshot, PHASES};

/// Run `secure_infer_batch` (setup + one 2-request window) over
/// pre-built endpoints; returns P1's logits and the shared meter.
fn run_window_over(
    nets: [ppq_bert::transport::Net; 3],
    metrics: &Arc<Metrics>,
    scfg: SessionCfg,
) -> (Vec<Vec<i64>>, MetricsSnapshot) {
    let cfg = BertConfig::tiny();
    let (weights, _) = prepared_model(cfg);
    let inputs = prepared_inputs(&cfg, 2);
    let mut p1_logits = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for net in nets {
            let (weights, inputs) = (&weights, &inputs);
            handles.push(s.spawn(move || {
                let ctx = PartyCtx::new(net.id, net, scfg.master_seed, scfg.threads);
                let w = (ctx.id == P0).then_some(weights);
                let model = GraphSpec::new(TaskKind::Classify, cfg).build(&ctx, w);
                let x = (ctx.id == P1).then(|| inputs.clone());
                let (logits, _) = secure_infer_batch(&ctx, &model, 2, x.as_deref());
                ctx.flush_timer();
                (ctx.id, logits)
            }));
        }
        for h in handles {
            let (id, logits) = h.join().expect("party thread panicked");
            if id == P1 {
                p1_logits = logits;
            }
        }
    });
    (p1_logits, metrics.snapshot())
}

#[test]
fn tcp_backend_matches_mesh_bit_for_bit() {
    let scfg = SessionCfg::default();

    let mesh_metrics = Arc::new(Metrics::new());
    let mesh_nets = build_mesh(Arc::clone(&mesh_metrics), None);
    let (mesh_logits, mesh_snap) = run_window_over(mesh_nets, &mesh_metrics, scfg);

    let tcp_metrics = Arc::new(Metrics::new());
    let tcp_nets =
        loopback_mesh(Arc::clone(&tcp_metrics), scfg.master_seed, None).expect("loopback mesh");
    let (tcp_logits, tcp_snap) = run_window_over(tcp_nets, &tcp_metrics, scfg);

    // Bit-identical logits: all randomness comes from the seeded PRGs,
    // so the transport must not influence a single share.
    assert!(!mesh_logits.is_empty() && mesh_logits[0].len() == BertConfig::tiny().n_classes);
    assert_eq!(mesh_logits, tcp_logits);

    // Identical meter: bytes and messages per directed link, rounds per
    // party, for every phase (compute_ns is wall time and may differ).
    assert_eq!(mesh_snap.bytes, tcp_snap.bytes, "per-link bytes diverged across backends");
    assert_eq!(mesh_snap.msgs, tcp_snap.msgs, "per-link messages diverged across backends");
    assert_eq!(mesh_snap.rounds, tcp_snap.rounds, "per-party rounds diverged across backends");
    for phase in PHASES {
        assert_eq!(mesh_snap.total_bytes(phase), tcp_snap.total_bytes(phase));
        assert_eq!(mesh_snap.max_rounds(phase), tcp_snap.max_rounds(phase));
    }
    assert!(mesh_snap.total_bytes(ppq_bert::transport::Phase::Online) > 0);
}

#[test]
fn wire_frame_roundtrip_over_a_socket() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let payload: Vec<u8> = (0..100_000u32).map(|i| i as u8).collect();
    let sent = payload.clone();
    let t = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        wire::write_frame(&mut s, Tag::Online, &sent).unwrap();
        wire::write_frame(&mut s, Tag::Done, &[]).unwrap();
    });
    let (mut conn, _) = listener.accept().unwrap();
    let (tag, got) = wire::read_frame(&mut conn).unwrap();
    assert_eq!((tag, got), (Tag::Online, payload));
    let (tag, got) = wire::read_frame(&mut conn).unwrap();
    assert_eq!((tag, got.len()), (Tag::Done, 0));
    t.join().unwrap();
}

#[test]
fn handshake_rejects_wrong_party_id() {
    let session = *b"handshake-test-1";
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // The dialer believes it is connecting to party 2, but party 1
    // answers: the acceptor must error (and therefore never ack, so the
    // dialer fails symmetrically).
    let t = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        wire::dial_handshake(&mut s, PartyHello { session, from: 0, to: 2 })
    });
    let (mut conn, _) = listener.accept().unwrap();
    let err = wire::accept_handshake(&mut conn, &session, 1, 0).unwrap_err();
    assert!(err.to_string().contains("reached party 1"), "{err}");
    drop(conn); // close so the dialer's pending ack read fails
    assert!(t.join().unwrap().is_err());
}

#[test]
fn handshake_rejects_wrong_session() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let t = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        let _ = wire::dial_handshake(
            &mut s,
            PartyHello { session: *b"one-session-id-A", from: 2, to: 1 },
        );
    });
    let (mut conn, _) = listener.accept().unwrap();
    let err = wire::accept_handshake(&mut conn, b"other-session-id", 1, 0).unwrap_err();
    assert!(err.to_string().contains("session"), "{err}");
    drop(conn);
    t.join().unwrap();
}

#[test]
fn handshake_accepts_matching_party() {
    let session = *b"handshake-test-2";
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let t = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        wire::dial_handshake(&mut s, PartyHello { session, from: 2, to: 0 })
    });
    let (mut conn, _) = listener.accept().unwrap();
    match wire::accept_handshake(&mut conn, &session, 0, 0).unwrap() {
        Accepted::Party(from) => assert_eq!(from, 2),
        _ => panic!("expected a party link"),
    }
    t.join().unwrap().unwrap();
}

#[test]
fn session_id_binds_model_shape() {
    // Parties (or clients) configured for different model shapes must
    // fail the handshake at connect time, not deadlock mid-request.
    let seed = SessionCfg::default().master_seed;
    let tiny = BertConfig::tiny();
    let mut other = tiny;
    other.seq_len *= 2;
    assert_ne!(session_id(seed, &tiny), session_id(seed, &other));
    assert_eq!(session_id(seed, &tiny), session_id(seed, &BertConfig::tiny()));
    // ...and different deployment labels must not mesh either.
    use ppq_bert::coordinator::remote::seed_from_label;
    assert_ne!(seed_from_label("ci"), seed_from_label("prod"));
    assert_ne!(session_id(seed_from_label("ci"), &tiny), session_id(seed, &tiny));
}

/// Full multi-process-shape deployment on localhost (three `run_party`
/// bodies as threads — the process version is exercised by
/// `tools/smoke_multiprocess.sh` / `make smoke`): a remote client's
/// logits must equal the in-process coordinator's for the same model,
/// seed, and input, and the merged per-party meters must equal the
/// in-process session meter.
#[test]
fn remote_deployment_matches_in_process_coordinator() {
    let cfg = BertConfig::tiny();

    // In-process reference (default weights seed 42, input seed 11 —
    // the same pair prepared_model/`repro infer` use).
    let (weights, x) = prepared_model(cfg);
    let mut coord = Coordinator::start(ServerConfig::new(cfg), weights);
    coord.submit(x.clone());
    let local_logits = coord.run_batch().pop().expect("one result").logits;
    let local_snap = coord.snapshot();
    coord.shutdown();

    // Three party "processes" over real loopback sockets.
    let listeners: Vec<TcpListener> =
        (0..3).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
    let addrs: [String; 3] = listeners
        .iter()
        .map(|l| l.local_addr().unwrap().to_string())
        .collect::<Vec<_>>()
        .try_into()
        .unwrap();
    let session = session_id(SessionCfg::default().master_seed, &cfg);
    let mut handles = Vec::new();
    for (id, listener) in listeners.into_iter().enumerate() {
        let mut opts = PartyOpts::new(id, cfg);
        for p in 0..3 {
            if p != id {
                opts.peers[p] = Some(addrs[p].clone());
            }
        }
        handles.push(std::thread::spawn(move || run_party(listener, opts)));
    }

    let mut client =
        RemoteClient::connect(&addrs, session, Duration::from_secs(20)).expect("connect");
    let remote_logits = client.infer(&x).expect("remote inference");
    assert_eq!(remote_logits, local_logits, "remote deployment diverged from in-process run");

    // Merged per-party meters == the shared in-process meter.
    let merged = client.snapshot().expect("metrics");
    assert_eq!(merged.bytes, local_snap.bytes);
    assert_eq!(merged.msgs, local_snap.msgs);
    assert_eq!(merged.rounds, local_snap.rounds);

    // A mis-shaped request is refused cleanly at the admission point
    // (P1, the sequencer — no other party ever learns about it) and the
    // deployment must stay up and keep serving afterwards.
    let err = client.infer(&x[..x.len() - 1]).unwrap_err();
    assert!(err.to_string().contains("refused"), "{err}");
    let again = client.infer(&x).expect("deployment still serving after a refusal");
    assert_eq!(again.len(), cfg.n_classes);

    client.shutdown().expect("shutdown");
    for h in handles {
        h.join().expect("party thread").expect("party exited with error");
    }
}
