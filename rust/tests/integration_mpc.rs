//! End-to-end MPC-vs-plaintext integration: the secure pipeline must track
//! the native oracle (which in turn matches the python/XLA artifact)
//! within the local-truncation carry budget.

use ppq_bert::model::config::{BertConfig, TaskKind};
use ppq_bert::model::secure::{secure_infer, GraphSpec};
use ppq_bert::model::weights::{synth_input, Weights};
use ppq_bert::party::{run_3pc, SessionCfg, P0, P1};
use ppq_bert::runtime::native;
use ppq_bert::sharing::additive::reveal2;
use ppq_bert::transport::Phase;

fn tiny_setup() -> (BertConfig, Weights, Vec<i64>) {
    let cfg = BertConfig::tiny();
    let mut w = Weights::synth(cfg, 42);
    let xc = synth_input(&cfg, 5);
    native::calibrate(&cfg, &mut w, &xc);
    let x = synth_input(&cfg, 11);
    (cfg, w, x)
}

#[test]
fn secure_infer_tracks_native_oracle() {
    let (cfg, w, x) = tiny_setup();
    let (logits_ref, h_ref) = native::forward(&cfg, &w, &x);

    let xin = x.clone();
    let (outs, snap) = run_3pc(SessionCfg::default(), move |ctx| {
        let weights = if ctx.id == P0 { Some(&w) } else { None };
        let m = GraphSpec::new(TaskKind::Classify, cfg).build(ctx,weights);
        let (logits, h4) = secure_infer(ctx, &m, if ctx.id == P1 { Some(&xin) } else { None });
        let h_rev = reveal2(ctx, &h4);
        (logits, h_rev)
    });
    let (logits_mpc, h_mpc_enc) = &outs[1];
    assert_eq!(logits_mpc.len(), cfg.n_classes);

    // Final hidden states: the MPC pipeline accumulates −1 LSB carries at
    // every local truncation (the paper's probabilistic-truncation-grade
    // accuracy, footnote 2). After 2 layers the measured budget is:
    // ~90% of values within 1 LSB, mean |dev| ≈ 0.9, worst-case a few LSB.
    let h_mpc: Vec<i64> = h_mpc_enc.iter().map(|&v| (((v & 0xF) ^ 8) as i64) - 8).collect();
    let mut within1 = 0usize;
    let mut total = 0i64;
    for (i, (&got, &want)) in h_mpc.iter().zip(&h_ref).enumerate() {
        let d = (got - want).abs();
        assert!(d <= 6, "hidden[{i}] got {got} want {want}");
        total += d;
        if d <= 1 {
            within1 += 1;
        }
    }
    assert!(
        within1 * 4 >= h_ref.len() * 3,
        "only {within1}/{} hidden values within 1 LSB",
        h_ref.len()
    );
    let mean = total as f64 / h_ref.len() as f64;
    assert!(mean <= 1.2, "mean |dev| {mean}");

    // Logits: bounded by the hidden deviation propagated through the
    // classifier (|Δlogit| ≤ scale_cls · Σ|Δh_cls|).
    for (a, b) in logits_mpc.iter().zip(&logits_ref) {
        assert!(
            (a - b).abs() <= cfg.scale_cls * 3 * cfg.d_model as i64,
            "logit gap too large: {logits_mpc:?} vs {logits_ref:?}"
        );
    }

    // Communication sanity: online ≪ offline (the paper's headline shape).
    let online = snap.total_bytes(Phase::Online);
    let offline = snap.total_bytes(Phase::Offline);
    assert!(online > 0 && offline > online, "online {online} offline {offline}");
}

#[test]
fn secure_infer_is_deterministic_given_seed() {
    let (cfg, w, x) = tiny_setup();
    let run = || {
        let (w2, xin) = (clone_weights(&w, cfg), x.clone());
        let (outs, _) = run_3pc(SessionCfg::default(), move |ctx| {
            let m = GraphSpec::new(TaskKind::Classify, cfg).build(ctx,if ctx.id == P0 { Some(&w2) } else { None });
            secure_infer(ctx, &m, if ctx.id == P1 { Some(&xin) } else { None }).0
        });
        outs[1].clone()
    };
    assert_eq!(run(), run());
}

#[test]
fn different_inputs_give_different_outputs() {
    let (cfg, w, x) = tiny_setup();
    let x2 = synth_input(&cfg, 77);
    let run = |input: Vec<i64>| {
        let w2 = clone_weights(&w, cfg);
        let (outs, _) = run_3pc(SessionCfg::default(), move |ctx| {
            let m = GraphSpec::new(TaskKind::Classify, cfg).build(ctx,if ctx.id == P0 { Some(&w2) } else { None });
            let (_, h) = secure_infer(ctx, &m, if ctx.id == P1 { Some(&input) } else { None });
            reveal2(ctx, &h)
        });
        outs[1].clone()
    };
    let h1 = run(x);
    let h2 = run(x2);
    let diff = h1.iter().zip(&h2).filter(|(a, b)| a != b).count();
    assert!(diff * 10 > h1.len(), "only {diff}/{} differ", h1.len());
}

fn clone_weights(w: &Weights, cfg: BertConfig) -> Weights {
    Weights {
        cfg,
        tensors: w.tensors.clone(),
        scales: w.scales.clone(),
    }
}

#[test]
fn single_head_single_token_edge_config() {
    // Degenerate shapes: seq_len 1 (softmax over one score), 1 head.
    let mut cfg = BertConfig::tiny();
    cfg.seq_len = 1;
    cfg.n_heads = 1;
    cfg.n_layers = 1;
    let mut w = Weights::synth(cfg, 9);
    native::calibrate(&cfg, &mut w, &synth_input(&cfg, 1));
    let x = synth_input(&cfg, 2);
    let (_, h_ref) = native::forward(&cfg, &w, &x);
    let xin = x.clone();
    let (outs, _) = run_3pc(SessionCfg::default(), move |ctx| {
        let m = GraphSpec::new(TaskKind::Classify, cfg).build(ctx,if ctx.id == P0 { Some(&w) } else { None });
        let (_, h) = secure_infer(ctx, &m, if ctx.id == P1 { Some(&xin) } else { None });
        reveal2(ctx, &h)
    });
    let h_mpc: Vec<i64> = outs[1].iter().map(|&v| (((v & 0xF) ^ 8) as i64) - 8).collect();
    for (i, (&g, &want)) in h_mpc.iter().zip(&h_ref).enumerate() {
        assert!((g - want).abs() <= 3, "h[{i}] {g} vs {want}");
    }
}

#[test]
fn extreme_inputs_saturate_gracefully() {
    // All-max / all-min inputs must not wrap into garbage anywhere.
    let (cfg, w, _) = tiny_setup();
    for fill in [7i64, -8] {
        let x = vec![fill; cfg.seq_len * cfg.d_model];
        let (_, h_ref) = native::forward(&cfg, &w, &x);
        let (wc, xin) = (clone_weights(&w, cfg), x.clone());
        let (outs, _) = run_3pc(SessionCfg::default(), move |ctx| {
            let m = GraphSpec::new(TaskKind::Classify, cfg).build(ctx,if ctx.id == P0 { Some(&wc) } else { None });
            let (_, h) = secure_infer(ctx, &m, if ctx.id == P1 { Some(&xin) } else { None });
            reveal2(ctx, &h)
        });
        let h_mpc: Vec<i64> = outs[1].iter().map(|&v| (((v & 0xF) ^ 8) as i64) - 8).collect();
        let mut off = 0usize;
        for (&g, &want) in h_mpc.iter().zip(&h_ref) {
            assert!((g - want).abs() <= 6, "fill {fill}: {g} vs {want}");
            if (g - want).abs() > 1 { off += 1; }
        }
        assert!(off * 2 <= h_ref.len(), "fill {fill}: {off} values beyond carry");
    }
}

#[test]
fn thread_count_does_not_change_results() {
    let (cfg, w, x) = tiny_setup();
    let run = |threads: usize| {
        let (wc, xin) = (clone_weights(&w, cfg), x.clone());
        let mut scfg = SessionCfg::default();
        scfg.threads = threads;
        let (outs, _) = run_3pc(scfg, move |ctx| {
            let m = GraphSpec::new(TaskKind::Classify, cfg).build(ctx,if ctx.id == P0 { Some(&wc) } else { None });
            secure_infer(ctx, &m, if ctx.id == P1 { Some(&xin) } else { None }).0
        });
        outs[1].clone()
    };
    assert_eq!(run(1), run(3));
}

#[test]
fn secure_classify_matches_plaintext_argmax_class() {
    use ppq_bert::model::secure::secure_classify;
    let (cfg, w, x) = tiny_setup();
    let (logits_ref, _) = native::forward(&cfg, &w, &x);
    // plaintext class from the *requantized* logits (the protocol
    // compares trc(logits,4), matching Alg. 3 semantics)
    let q: Vec<i64> = logits_ref.iter().map(|&v| (((v as u64 & 0xFFFF) >> 12) as i64 + 8) % 16 - 8).collect();
    let want = q.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0 as u64;
    let (wc, xin) = (clone_weights(&w, cfg), x.clone());
    let (outs, _) = run_3pc(SessionCfg::default(), move |ctx| {
        let weights = if ctx.id == P0 { Some(&wc) } else { None };
        let m = GraphSpec::new(TaskKind::Classify, cfg).build_argmax(ctx, weights);
        secure_classify(ctx, &m, if ctx.id == P1 { Some(&xin) } else { None })
    });
    // classes must agree across P1/P2 and be in range; with carry noise the
    // class can flip only when logits are within one trc step of a tie.
    assert_eq!(outs[1], outs[2]);
    assert!(outs[1] < cfg.n_classes as u64);
    if (q[0] - q[1]).abs() > 2 {
        assert_eq!(outs[1], want, "q={q:?}");
    }
}
