//! Deterministic fault injection against REAL party processes
//! (DESIGN.md §Durability & recovery): kill a party mid-deployment —
//! via the wire-armed abort (`--fault-window` / `Tag::Fault`, which
//! dies by `std::process::abort()` exactly at a chosen window's
//! manifest) or a literal `SIGKILL` — and prove the recovery story
//! end-to-end:
//!
//! * the window riding the killed party is refused SYMMETRICALLY (one
//!   clean `Refused` frame from P1, no hanging client, no partial
//!   answers from P0/P2);
//! * a party restarted with the same `--tape-dir` rejoins warm: the
//!   retried window consumes a persisted correlation tape (ZERO
//!   request-path offline bytes) and its logits are bit-identical to an
//!   in-process session;
//! * survivors that exhaust their reconnect budget refuse their queue
//!   and drain with exit code 0 — a lost deployment never wedges;
//! * the control plane recovers too: killing the SEQUENCER drops both
//!   control links, and a restarted P1 re-dials them and resumes
//!   serving new clients.
//!
//! Every scenario spawns the actual `repro` binary (three OS processes
//! over loopback TCP), so the recovery paths exercised here are the
//! ones a real deployment runs — not in-process approximations.

use std::path::PathBuf;
use std::process::{Child, Command, ExitStatus, Stdio};
use std::time::{Duration, Instant};

use ppq_bert::bench_harness::prepared_model;
use ppq_bert::coordinator::remote::{arm_fault, session_id, RemoteClient};
use ppq_bert::coordinator::Session;
use ppq_bert::model::config::BertConfig;
use ppq_bert::model::weights::synth_input;
use ppq_bert::party::SessionCfg;
use ppq_bert::protocols::max::MaxStrategy;

const BIN: &str = env!("CARGO_BIN_EXE_repro");

/// The three party addresses of one test deployment (each test uses its
/// own port base so the tests can run in parallel).
fn party_addrs(base: u16) -> [String; 3] {
    [0u16, 1, 2].map(|i| format!("127.0.0.1:{}", base + i))
}

/// Per-(test, party) tape directories, wiped ONCE at deployment start —
/// a restart reuses the surviving on-disk state, which is the point.
fn fresh_tape_dirs(tag: &str) -> [PathBuf; 3] {
    [0usize, 1, 2].map(|id| {
        let dir = std::env::temp_dir().join(format!("ppq_fault_{tag}_p{id}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    })
}

/// Spawn one `repro party` process with the deployment's addresses plus
/// per-test extra flags.
fn spawn_party(base: u16, id: usize, extra: &[String]) -> Child {
    let addrs = party_addrs(base);
    let peers: Vec<String> = (0..3).filter(|&p| p != id).map(|p| addrs[p].clone()).collect();
    let mut cmd = Command::new(BIN);
    cmd.args(["party", "--id", &id.to_string(), "--listen", &addrs[id]]);
    cmd.args(["--peers", &peers.join(",")]);
    cmd.args(extra);
    // Quiet by default: recovery progress goes to stderr and the
    // interesting state is asserted over the wire.
    cmd.stdout(Stdio::null()).stderr(Stdio::null());
    cmd.spawn().expect("spawn party process")
}

/// Kill-on-drop guard so a failing assertion never leaks live party
/// processes into the test runner.
struct Procs(Vec<Child>);

impl Drop for Procs {
    fn drop(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Wait (bounded) for a process to exit on its own.
fn wait_exit(child: &mut Child, timeout: Duration) -> ExitStatus {
    let deadline = Instant::now() + timeout;
    loop {
        if let Some(st) = child.try_wait().expect("poll child") {
            return st;
        }
        if Instant::now() >= deadline {
            let _ = child.kill();
            panic!("process did not exit within {timeout:?}");
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

fn oracle_logits(cfg: BertConfig, inputs: &[Vec<i64>]) -> Vec<Vec<i64>> {
    let (w, _) = prepared_model(cfg);
    let sess = Session::start(cfg, w, SessionCfg::default(), MaxStrategy::Tournament);
    let out = inputs.iter().map(|x| sess.infer_batch(std::slice::from_ref(x)).remove(0)).collect();
    sess.shutdown();
    out
}

/// A party dying mid-window is REFUSED symmetrically (one clean frame
/// from P1, the client's wait returns an error, nothing hangs) — and
/// when nobody restarts the dead party, the survivors exhaust their
/// reconnect budget, refuse everything queued, and DRAIN with exit
/// code 0. A lost deployment must never wedge.
#[test]
fn killed_party_mid_window_refuses_cleanly_and_survivors_drain() {
    let cfg = BertConfig::tiny();
    let base = 9310;
    let budget =
        ["--reconnect-attempts", "3", "--reconnect-backoff-ms", "200"].map(String::from).to_vec();
    let mut procs = Procs(Vec::new());
    procs.0.push(spawn_party(base, 0, &budget));
    procs.0.push(spawn_party(base, 1, &budget));
    let mut p2_flags = budget.clone();
    p2_flags.extend(["--fault-window", "0"].map(String::from));
    procs.0.push(spawn_party(base, 2, &p2_flags));

    let session = session_id(SessionCfg::default().master_seed, &cfg);
    let mut client = RemoteClient::connect(&party_addrs(base), session, Duration::from_secs(120))
        .expect("connect");
    let id = client.submit(&synth_input(&cfg, 500)).expect("submit");
    let err = client.wait(id).expect_err("the window riding the killed party must be refused");
    assert!(err.to_string().contains("refused"), "unexpected failure shape: {err}");

    // P2 died by abort (non-zero), the survivors drained cleanly (zero):
    // P1 after refusing its queue, P0 after its reconnect budget ran dry.
    assert!(!wait_exit(&mut procs.0[2], Duration::from_secs(60)).success(), "P2 should abort");
    assert!(wait_exit(&mut procs.0[1], Duration::from_secs(120)).success(), "P1 should drain");
    assert!(wait_exit(&mut procs.0[0], Duration::from_secs(120)).success(), "P0 should drain");
}

/// THE durability acceptance pin: kill P2 at window 1 via the armed
/// fault, restart it with the same `--tape-dir`, and the deployment
/// recovers WARM — the retried window consumes a persisted correlation
/// tape (zero request-path offline bytes on every party), its logits
/// are bit-identical to an in-process session over the same inputs, and
/// every party reports recovery epoch 1.
#[test]
fn restarted_party_with_tape_dir_serves_next_window_warm_and_bit_identical() {
    let cfg = BertConfig::tiny();
    let base = 9320;
    let addrs = party_addrs(base);
    let dirs = fresh_tape_dirs("warm");
    let flags = |id: usize| -> Vec<String> {
        let mut f = ["--max-batch", "1", "--prep", "3"].map(String::from).to_vec();
        let recon = ["--reconnect-attempts", "150", "--reconnect-backoff-ms", "200"];
        f.extend(recon.map(String::from));
        f.push("--tape-dir".into());
        f.push(dirs[id].to_string_lossy().into_owned());
        f
    };
    let mut procs = Procs((0..3).map(|id| spawn_party(base, id, &flags(id))).collect());
    let session = session_id(SessionCfg::default().master_seed, &cfg);

    let xa = synth_input(&cfg, 510);
    let xb = synth_input(&cfg, 511);
    let mut c1 = RemoteClient::connect(&addrs, session, Duration::from_secs(120)).expect("connect");
    let ida = c1.submit(&xa).expect("submit a");
    let done_a = c1.wait(ida).expect("wait a");
    // Prefill made even the FIRST window warm.
    assert_eq!(done_a.window_offline_bytes(), 0, "prefilled window 0 should be warm");

    // Arm the abort at window 1 (acked before we submit the request
    // that trips it), then watch that window get refused.
    arm_fault(&addrs[2], session, 1, Duration::from_secs(30)).expect("arm fault");
    let idb = c1.submit(&xb).expect("submit b");
    let err = c1.wait(idb).expect_err("window 1 must be refused when P2 aborts");
    assert!(err.to_string().contains("refused"), "unexpected failure shape: {err}");
    assert!(!wait_exit(&mut procs.0[2], Duration::from_secs(60)).success(), "P2 should abort");
    drop(c1); // its P2 connection died with the old process

    // Restart P2 against the SAME flags — including the same tape dir,
    // which now holds the pre-crash pool and boundary snapshot.
    let restart_flags = flags(2);
    procs.0[2] = spawn_party(base, 2, &restart_flags);
    let mut c2 = RemoteClient::connect(&addrs, session, Duration::from_secs(120))
        .expect("reconnect after restart");
    let idb2 = c2.submit(&xb).expect("resubmit b");
    let done_b = c2.wait(idb2).expect("retried window must serve after the warm rejoin");

    // Warm: the retried window consumed a persisted tape — zero
    // request-path offline bytes summed over all three parties.
    assert_eq!(
        done_b.window_offline_bytes(),
        0,
        "retried window after crash-restart should be served from the durable pool"
    );

    // Bit-identical to an uninterrupted in-process session.
    let oracle = oracle_logits(cfg, &[xa, xb]);
    assert_eq!(done_a.logits, oracle[0], "pre-fault logits diverged");
    assert_eq!(done_b.logits, oracle[1], "post-recovery logits diverged");

    // Every party counts exactly one completed recovery, and P1's
    // latency histogram saw both completed windows.
    for p in 0..3 {
        let s = c2.stats(p).expect("stats");
        assert_eq!(s.epoch, 1, "party {p} recovery epoch");
    }
    let s1 = c2.stats(1).expect("stats p1");
    assert!(s1.lat_hist.iter().sum::<u64>() >= 2, "latency histogram should cover both windows");
    assert!(s1.tapes <= 3, "tape gauge should stay bounded by prep depth");

    c2.shutdown().expect("drain");
    for p in [0usize, 1, 2] {
        assert!(wait_exit(&mut procs.0[p], Duration::from_secs(120)).success(), "party {p}");
    }
}

/// Killing the SEQUENCER kills both control links — the follower-side
/// trigger is a dead control read, not a protocol abort. A P1 restarted
/// with its `--tape-dir` must rejoin the mesh, re-dial fresh control
/// links, and serve new clients.
#[test]
fn sequencer_restart_resumes_service_for_new_clients() {
    let cfg = BertConfig::tiny();
    let base = 9330;
    let addrs = party_addrs(base);
    let dirs = fresh_tape_dirs("seq");
    let flags = |id: usize| -> Vec<String> {
        let recon = ["--reconnect-attempts", "150", "--reconnect-backoff-ms", "200"];
        let mut f = recon.map(String::from).to_vec();
        f.push("--tape-dir".into());
        f.push(dirs[id].to_string_lossy().into_owned());
        f
    };
    let mut procs = Procs((0..3).map(|id| spawn_party(base, id, &flags(id))).collect());
    let session = session_id(SessionCfg::default().master_seed, &cfg);

    let xa = synth_input(&cfg, 520);
    let xb = synth_input(&cfg, 521);
    let mut c1 = RemoteClient::connect(&addrs, session, Duration::from_secs(120)).expect("connect");
    let la = c1.infer(&xa).expect("pre-kill window");
    drop(c1);

    // SIGKILL the idle sequencer, then restart it against its tape dir.
    procs.0[1].kill().expect("kill -9 P1");
    let _ = procs.0[1].wait();
    let restart_flags = flags(1);
    procs.0[1] = spawn_party(base, 1, &restart_flags);

    let mut c2 = RemoteClient::connect(&addrs, session, Duration::from_secs(120))
        .expect("reconnect after sequencer restart");
    let idb = c2.submit(&xb).expect("submit after restart");
    let done_b = c2.wait(idb).expect("restarted sequencer must serve new clients");

    let oracle = oracle_logits(cfg, &[xa, xb]);
    assert_eq!(la, oracle[0], "pre-kill logits diverged");
    assert_eq!(done_b.logits, oracle[1], "post-restart logits diverged");
    // The surviving followers each completed one recovery.
    for p in [0usize, 2] {
        assert_eq!(c2.stats(p).expect("stats").epoch, 1, "party {p} recovery epoch");
    }

    c2.shutdown().expect("drain");
    for p in [0usize, 1, 2] {
        assert!(wait_exit(&mut procs.0[p], Duration::from_secs(120)).success(), "party {p}");
    }
}

/// The CLI end of the story: `repro loadgen --fault party:2@window:1
/// --check` drives a deployment into the fault, tolerates the refusal,
/// and replays every COMPLETED window through a fresh in-process
/// session demanding bit-identical logits — green around a real crash
/// plus restart.
#[test]
fn loadgen_fault_check_replays_completed_windows() {
    let cfg = BertConfig::tiny();
    let base = 9340;
    let addrs = party_addrs(base);
    let dirs = fresh_tape_dirs("loadgen");
    let flags = |id: usize| -> Vec<String> {
        let mut f = ["--max-batch", "1"].map(String::from).to_vec();
        let recon = ["--reconnect-attempts", "150", "--reconnect-backoff-ms", "200"];
        f.extend(recon.map(String::from));
        f.push("--tape-dir".into());
        f.push(dirs[id].to_string_lossy().into_owned());
        f
    };
    let mut procs = Procs((0..3).map(|id| spawn_party(base, id, &flags(id))).collect());

    let mut loadgen = Command::new(BIN)
        .args(["loadgen", "--clients", "1", "--requests", "2"])
        .args(["--remote", &addrs.join(",")])
        .args(["--fault", "party:2@window:1", "--check"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn loadgen");

    // The armed fault kills P2 at window 1; restart it so loadgen's
    // post-run probe (and the deployment) can recover.
    assert!(!wait_exit(&mut procs.0[2], Duration::from_secs(120)).success(), "P2 should abort");
    procs.0[2] = spawn_party(base, 2, &flags(2)[..]);

    let out = loadgen.wait_with_output().expect("loadgen output");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "loadgen failed:\n{stdout}");
    assert!(stdout.contains("fault armed"), "fault was not armed:\n{stdout}");
    assert!(stdout.contains("refused 1 of 2"), "expected exactly one refusal:\n{stdout}");
    assert!(stdout.contains("CHECK OK"), "completed windows failed the replay check:\n{stdout}");

    // The recovered deployment still serves, then drains cleanly.
    let session = session_id(SessionCfg::default().master_seed, &cfg);
    let mut client = RemoteClient::connect(&addrs, session, Duration::from_secs(120))
        .expect("post-recovery connect");
    let logits = client.infer(&synth_input(&cfg, 530)).expect("post-recovery inference");
    assert_eq!(logits.len(), cfg.n_classes);
    client.shutdown().expect("drain");
    for p in [0usize, 1, 2] {
        assert!(wait_exit(&mut procs.0[p], Duration::from_secs(120)).success(), "party {p}");
    }
}
