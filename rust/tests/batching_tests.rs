//! Cross-request batching integration: a window of B requests evaluated
//! by `secure_infer_batch` must (a) produce the same logits as B
//! independent `secure_infer` calls up to the local-truncation carry
//! budget, and (b) cost the SAME number of online rounds as a single
//! request — that equality is the amortization the serving layer sells.

use ppq_bert::bench_harness::{prepared_inputs, prepared_model};
use ppq_bert::coordinator::{Coordinator, ServerConfig};
use ppq_bert::model::config::{BertConfig, TaskKind};
use ppq_bert::model::secure::{secure_infer, secure_infer_batch, GraphSpec};
use ppq_bert::model::weights::Weights;
use ppq_bert::party::{run_3pc, SessionCfg, P0, P1};
use ppq_bert::protocols::max::MaxStrategy;
use ppq_bert::transport::Phase;

fn clone_weights(w: &Weights, cfg: BertConfig) -> Weights {
    Weights {
        cfg,
        tensors: w.tensors.clone(),
        scales: w.scales.clone(),
    }
}

/// Carry tolerance used by the session tests: batched and independent
/// runs draw different share randomness, so logits may differ by the
/// accumulated −1 LSB truncation carries, bounded through the classifier.
fn carry_tolerance(cfg: &BertConfig) -> i64 {
    cfg.scale_cls * 2 * cfg.d_model as i64
}

#[test]
fn batched_logits_match_independent_inference() {
    let cfg = BertConfig::tiny();
    let (w, _) = prepared_model(cfg);
    let batch = 3usize;
    let inputs = prepared_inputs(&cfg, batch);

    let (wc, inc) = (clone_weights(&w, cfg), inputs.clone());
    let (outs, _) = run_3pc(SessionCfg::default(), move |ctx| {
        let m = GraphSpec::new(TaskKind::Classify, cfg).build(ctx,if ctx.id == P0 { Some(&wc) } else { None });
        let (batched, h4) = secure_infer_batch(
            ctx,
            &m,
            batch,
            if ctx.id == P1 { Some(&inc) } else { None },
        );
        assert_eq!(h4.len, batch * cfg.seq_len * cfg.d_model);
        // same session, same model shares: per-request singles
        let singles: Vec<Vec<i64>> = inc
            .iter()
            .map(|x| {
                secure_infer(ctx, &m, if ctx.id == P1 { Some(x) } else { None }).0
            })
            .collect();
        (batched, singles)
    });
    let (batched, singles) = &outs[1]; // P1's revealed logits
    assert_eq!(batched.len(), batch);
    let tol = carry_tolerance(&cfg);
    for i in 0..batch {
        assert_eq!(batched[i].len(), cfg.n_classes);
        for (a, b) in batched[i].iter().zip(&singles[i]) {
            assert!(
                (a - b).abs() <= tol,
                "request {i}: batched {:?} vs single {:?}",
                batched[i],
                singles[i]
            );
        }
    }
    // P2 sees identical logits (both hold the opened values).
    assert_eq!(outs[1].0, outs[2].0);
    // P0 learns nothing.
    assert!(outs[0].0.iter().all(|l| l.is_empty()));
}

/// The amortization claim, measured: online rounds for a B = 4 window
/// equal the B = 1 round count exactly, while online bytes grow with B.
#[test]
fn batch_of_four_costs_single_request_rounds() {
    let cfg = BertConfig::tiny();

    let run = |batch: usize| -> (u64, u64, u64) {
        let (w, _) = prepared_model(cfg);
        let inputs = prepared_inputs(&cfg, batch);
        let (_, snap) = run_3pc(SessionCfg::default(), move |ctx| {
            let m = GraphSpec::new(TaskKind::Classify, cfg).build(ctx,if ctx.id == P0 { Some(&w) } else { None });
            secure_infer_batch(ctx, &m, batch, if ctx.id == P1 { Some(&inputs) } else { None });
        });
        (
            snap.max_rounds(Phase::Online),
            snap.max_rounds(Phase::Offline),
            snap.total_bytes(Phase::Online),
        )
    };

    let (rounds1, off_rounds1, bytes1) = run(1);
    let (rounds4, off_rounds4, bytes4) = run(4);
    assert_eq!(
        rounds4, rounds1,
        "online rounds must not grow with batch size"
    );
    assert_eq!(
        off_rounds4, off_rounds1,
        "offline rounds must not grow with batch size"
    );
    // bytes DO scale with the batch (rounds amortize, volume doesn't)
    assert!(
        bytes4 > bytes1 * 3,
        "expected ~4x online bytes, got {bytes1} -> {bytes4}"
    );
    assert!(rounds1 > 0 && bytes1 > 0);
}

/// Coordinator accounting: a full window is one MPC pass; per-request
/// results carry amortized byte shares that sum to the window total, and
/// the window's measured rounds match an unbatched window's.
#[test]
fn coordinator_amortizes_rounds_across_window() {
    let cfg = BertConfig::tiny();

    // Unbatched reference window.
    let single_rounds = {
        let (w, x) = prepared_model(cfg);
        let mut sc = ServerConfig::new(cfg);
        sc.max_batch = 1;
        let mut coord = Coordinator::start(sc, w);
        coord.submit(x);
        let r = coord.run_batch().remove(0);
        coord.shutdown();
        assert_eq!(r.batch_size, 1);
        r.window_online_rounds
    };

    let (w, _) = prepared_model(cfg);
    let mut sc = ServerConfig::new(cfg);
    sc.max_batch = 4;
    let mut coord = Coordinator::start(sc, w);
    let ids: Vec<u64> = prepared_inputs(&cfg, 4)
        .into_iter()
        .map(|x| coord.submit(x))
        .collect();
    let results = coord.run_batch();
    assert_eq!(results.len(), 4);
    assert_eq!(results.iter().map(|r| r.id).collect::<Vec<_>>(), ids);
    assert_eq!(coord.windows(), 1);

    let snap = coord.snapshot();
    let window_online = snap.total_bytes(Phase::Online);
    let window_offline = snap.total_bytes(Phase::Offline);
    for r in &results {
        assert_eq!(r.batch_size, 4);
        assert_eq!(
            r.window_online_rounds, single_rounds,
            "a 4-request window must cost single-request rounds"
        );
        assert!(r.online_bytes > 0);
    }
    // Amortized shares conserve the window totals exactly.
    assert_eq!(results.iter().map(|r| r.online_bytes).sum::<u64>(), window_online);
    assert_eq!(results.iter().map(|r| r.offline_bytes).sum::<u64>(), window_offline);
    coord.shutdown();
}

/// Batching composes with the serving knobs: a sorted-max session batched
/// at B = 2 still serves correct-shaped logits per request.
#[test]
fn batched_window_with_sort_strategy() {
    let cfg = BertConfig::tiny();
    let (w, _) = prepared_model(cfg);
    let mut sc = ServerConfig::new(cfg);
    sc.max_batch = 2;
    sc.max_strategy = MaxStrategy::Sort;
    let mut coord = Coordinator::start(sc, w);
    for x in prepared_inputs(&cfg, 2) {
        coord.submit(x);
    }
    let results = coord.run_batch();
    assert_eq!(results.len(), 2);
    for r in &results {
        assert_eq!(r.logits.len(), cfg.n_classes);
        assert_eq!(r.batch_size, 2);
    }
    coord.shutdown();
}
