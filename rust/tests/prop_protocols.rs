//! Property-based tests over the protocol suite (in-house mini framework,
//! rust/src/testing — the proptest crate is unavailable offline).
//!
//! Each property runs many seeded random cases across a 3-party session
//! and checks a protocol invariant end to end.

use ppq_bert::core::ring::{Ring, R16, R4, R6, R8};
use ppq_bert::party::{run_3pc, SessionCfg, P0, P1};
use ppq_bert::prop_assert;
use ppq_bert::protocols::convert::{convert_to_rss, extend_ring};
use ppq_bert::protocols::lut::{lut2_eval, lut_eval, LutTable, LutTable2};
use ppq_bert::protocols::matmul::{rss_matmul_full, rss_matmul_trc};
use ppq_bert::protocols::max::{max_rows, MaxStrategy};
use ppq_bert::protocols::softmax::{softmax_rows, SoftmaxTables};
use ppq_bert::protocols::tables;
use ppq_bert::sharing::additive::{reveal2, share2};
use ppq_bert::sharing::rss::{reveal_rss, share_rss};
use ppq_bert::testing::check;
use ppq_bert::transport::Phase;

const CASES: u64 = 12;

#[test]
fn prop_share2_reveal_roundtrip() {
    check("share2 o reveal == id (any ring, any owner)", 30, |g| {
        let ring = *g.pick(&[R4, R8, R16, Ring::new(32)]);
        let owner = g.usize_in(0, 2);
        let n = g.usize_in(1, 40);
        let secret = g.ring_vec(ring, n);
        let sc = secret.clone();
        let ([_, r1, r2], _) = run_3pc(SessionCfg::default(), move |ctx| {
            let sh = share2(ctx, owner, ring, if ctx.id == owner { Some(&sc) } else { None }, sc.len());
            reveal2(ctx, &sh)
        });
        prop_assert!(r1 == secret && r2 == secret, "owner {owner} ring {ring:?}");
        Ok(())
    });
}

#[test]
fn prop_rss_linearity() {
    check("RSS add/scale homomorphism", CASES, |g| {
        let ring = *g.pick(&[R16, Ring::new(32)]);
        let n = g.usize_in(1, 16);
        let a = g.ring_vec(ring, n);
        let b = g.ring_vec(ring, n);
        let c = g.ring_elem(ring);
        let (ac, bc) = (a.clone(), b.clone());
        let (outs, _) = run_3pc(SessionCfg::default(), move |ctx| {
            let x = share_rss(ctx, P0, ring, if ctx.id == P0 { Some(&ac) } else { None }, ac.len());
            let y = share_rss(ctx, P1, ring, if ctx.id == P1 { Some(&bc) } else { None }, bc.len());
            reveal_rss(ctx, &x.add(&y).scale(c))
        });
        for i in 0..n {
            let want = ring.mul(ring.add(a[i], b[i]), c);
            prop_assert!(outs[0][i] == want, "i {i}: {} != {want}", outs[0][i]);
        }
        Ok(())
    });
}

#[test]
fn prop_lut_computes_any_function() {
    check("Pi_look == f for random tables", CASES, |g| {
        let inr = *g.pick(&[R4, R6, R8]);
        let outr = *g.pick(&[R4, R8, R16]);
        let table: Vec<u64> = (0..inr.size()).map(|_| g.ring_elem(outr)).collect();
        let n = g.usize_in(1, 30);
        let xs = g.ring_vec(inr, n);
        let (tc, xc) = (table.clone(), xs.clone());
        let ([_, r1, _], _) = run_3pc(SessionCfg::default(), move |ctx| {
            let t = LutTable { in_ring: inr, out_ring: outr, entries: tc.clone() };
            let x = share2(ctx, P0, inr, if ctx.id == P0 { Some(&xc) } else { None }, xc.len());
            reveal2(ctx, &lut_eval(ctx, &t, &x))
        });
        for (i, &x) in xs.iter().enumerate() {
            prop_assert!(r1[i] == table[x as usize], "x {x}");
        }
        Ok(())
    });
}

#[test]
fn prop_lut2_matches_single_lut_composition() {
    check("Pi_look^{b1,b2}(x,y) == T[x||y]", CASES, |g| {
        let xr = *g.pick(&[R4, R6]);
        let yr = R4;
        let outr = R16;
        let table: Vec<u64> =
            (0..xr.size() * yr.size()).map(|_| g.ring_elem(outr)).collect();
        let n = g.usize_in(1, 20);
        let xs = g.ring_vec(xr, n);
        let ys = g.ring_vec(yr, n);
        let (tc, xc, yc) = (table.clone(), xs.clone(), ys.clone());
        let ([_, r1, _], _) = run_3pc(SessionCfg::default(), move |ctx| {
            let t = LutTable2 { x_ring: xr, y_ring: yr, out_ring: outr, entries: tc.clone() };
            let x = share2(ctx, P0, xr, if ctx.id == P0 { Some(&xc) } else { None }, xc.len());
            let y = share2(ctx, P0, yr, if ctx.id == P0 { Some(&yc) } else { None }, yc.len());
            reveal2(ctx, &lut2_eval(ctx, &t, &x, &y))
        });
        for i in 0..n {
            let want = table[(xs[i] as usize) * yr.size() + ys[i] as usize];
            prop_assert!(r1[i] == want, "i {i}");
        }
        Ok(())
    });
}

#[test]
fn prop_convert_preserves_signed_value() {
    check("Pi_convert^{l',l} == sign-extension", CASES, |g| {
        let from = *g.pick(&[R4, R6, R8]);
        let to = *g.pick(&[R16, Ring::new(32)]);
        let n = g.usize_in(1, 25);
        let vals: Vec<i64> = (0..n)
            .map(|_| g.i64_in(-(1 << (from.bits() - 1)), (1 << (from.bits() - 1)) - 1))
            .collect();
        let enc: Vec<u64> = vals.iter().map(|&v| from.encode(v)).collect();
        let vc = vals.clone();
        let (outs, _) = run_3pc(SessionCfg::default(), move |ctx| {
            let x = share2(ctx, P0, from, if ctx.id == P0 { Some(&enc) } else { None }, enc.len());
            reveal_rss(ctx, &convert_to_rss(ctx, &x, to, true))
        });
        for i in 0..n {
            prop_assert!(to.decode(outs[0][i]) == vc[i], "i {i}");
        }
        Ok(())
    });
}

#[test]
fn prop_matmul_full_exact() {
    check("RSS matmul == integer matmul (mod 2^16)", CASES, |g| {
        let rows = g.usize_in(1, 4);
        let k = g.usize_in(1, 12);
        let m = g.usize_in(1, 4);
        let x = g.signed_vec(4, rows * k);
        let w = g.signed_vec(8, m * k);
        let xe: Vec<u64> = x.iter().map(|&v| R16.encode(v)).collect();
        let we: Vec<u64> = w.iter().map(|&v| R16.encode(v)).collect();
        let (xc, wc) = (x.clone(), w.clone());
        let ([_, r1, _], _) = run_3pc(SessionCfg::default(), move |ctx| {
            let xs = share_rss(ctx, P1, R16, if ctx.id == P1 { Some(&xe) } else { None }, xe.len());
            let ws = share_rss(ctx, P0, R16, if ctx.id == P0 { Some(&we) } else { None }, we.len());
            reveal2(ctx, &rss_matmul_full(ctx, &xs, &ws, rows, k, m))
        });
        for r in 0..rows {
            for o in 0..m {
                let acc: i64 = (0..k).map(|j| xc[r * k + j] * wc[o * k + j]).sum();
                prop_assert!(r1[r * m + o] == R16.encode(acc), "r{r} o{o}");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_alg3_trc_at_most_one_carry() {
    check("Alg. 3 trc deviates by at most -1 LSB", CASES, |g| {
        let rows = g.usize_in(1, 3);
        let k = g.usize_in(1, 16);
        let m = g.usize_in(1, 3);
        let scale = g.i64_in(1, 512);
        let x = g.signed_vec(4, rows * k);
        let w: Vec<i64> = (0..m * k).map(|_| if g.u64_below(2) == 0 { -1 } else { 1 }).collect();
        let xe: Vec<u64> = x.iter().map(|&v| R16.encode(v)).collect();
        let we: Vec<u64> = w.iter().map(|&v| R16.encode(v * scale)).collect();
        let (xc, wc) = (x.clone(), w.clone());
        let ([_, r1, _], _) = run_3pc(SessionCfg::default(), move |ctx| {
            let xs = share_rss(ctx, P1, R16, if ctx.id == P1 { Some(&xe) } else { None }, xe.len());
            let ws = share_rss(ctx, P0, R16, if ctx.id == P0 { Some(&we) } else { None }, we.len());
            reveal2(ctx, &rss_matmul_trc(ctx, &xs, &ws, rows, k, m, 4))
        });
        for r in 0..rows {
            for o in 0..m {
                let acc: i64 = (0..k).map(|j| xc[r * k + j] * wc[o * k + j] * scale).sum();
                let exact = ((acc as u64) & 0xFFFF) >> 12;
                let got = r1[r * m + o];
                let deficit = (exact + 16 - got) % 16;
                prop_assert!(deficit <= 1, "r{r} o{o} got {got} exact {exact}");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_max_equals_plain_max() {
    check("Pi_max == max (both strategies)", CASES, |g| {
        let rows = g.usize_in(1, 3);
        let n = g.usize_in(1, 12);
        let vals = g.signed_vec(4, rows * n);
        let strat = *g.pick(&[MaxStrategy::Tournament, MaxStrategy::Linear]);
        let enc: Vec<u64> = vals.iter().map(|&v| R4.encode(v)).collect();
        let vc = vals.clone();
        let ([_, r1, _], _) = run_3pc(SessionCfg::default(), move |ctx| {
            let x = share2(ctx, P0, R4, if ctx.id == P0 { Some(&enc) } else { None }, enc.len());
            reveal2(ctx, &max_rows(ctx, &x, rows, n, strat))
        });
        for r in 0..rows {
            let want = *vc[r * n..(r + 1) * n].iter().max().unwrap();
            prop_assert!(R4.decode(r1[r]) == want, "row {r} strat {strat:?}");
        }
        Ok(())
    });
}

#[test]
fn prop_softmax_bit_exact_vs_oracle() {
    check("secure softmax == plaintext oracle (bit-exact)", CASES, |g| {
        let rows = g.usize_in(1, 3);
        let n = g.usize_in(2, 12);
        let sx = *g.pick(&[0.25f64, 0.5, 1.0]);
        let vals = g.signed_vec(4, rows * n);
        let enc: Vec<u64> = vals.iter().map(|&v| R4.encode(v)).collect();
        let ([_, r1, _], _) = run_3pc(SessionCfg::default(), move |ctx| {
            let t = SoftmaxTables::new(sx);
            let x = share2(ctx, P0, R4, if ctx.id == P0 { Some(&enc) } else { None }, enc.len());
            reveal2(ctx, &softmax_rows(ctx, &t, &x, rows, n, MaxStrategy::Tournament))
        });
        let want = ppq_bert::runtime::native::softmax_quant(&vals, rows, n, sx);
        for i in 0..rows * n {
            prop_assert!(r1[i] as i64 == want[i], "i {i}: {} != {}", r1[i], want[i]);
        }
        Ok(())
    });
}

#[test]
fn prop_extension_tables_consistent() {
    check("extend_ring(signed) == sign_extend everywhere", CASES, |g| {
        let n = g.usize_in(1, 20);
        let vals = g.ring_vec(R4, n);
        let vc = vals.clone();
        let ([_, r1, _], _) = run_3pc(SessionCfg::default(), move |ctx| {
            let x = share2(ctx, P0, R4, if ctx.id == P0 { Some(&vc) } else { None }, vc.len());
            reveal2(ctx, &extend_ring(ctx, &x, R16, true))
        });
        for (i, &v) in vals.iter().enumerate() {
            let want = ppq_bert::core::ring::sign_extend(v, R4, R16);
            prop_assert!(r1[i] == want, "i {i}");
        }
        Ok(())
    });
}

#[test]
fn prop_online_comm_independent_of_table_content() {
    // Security-adjacent invariant: online bytes depend only on shapes,
    // never on secret table contents or inputs.
    check("online comm is input-independent", 6, |g| {
        let n = g.usize_in(1, 30);
        let xs1 = g.ring_vec(R4, n);
        let xs2 = g.ring_vec(R4, n);
        let run = |xs: Vec<u64>| {
            let (_, snap) = run_3pc(SessionCfg::default(), move |ctx| {
                let t = tables::exp_table(0.5);
                let x = ctx.with_phase(Phase::Setup, |c| {
                    share2(c, P0, R4, if c.id == P0 { Some(&xs) } else { None }, xs.len())
                });
                lut_eval(ctx, &t, &x);
            });
            (
                snap.total_bytes(Phase::Online),
                snap.total_bytes(Phase::Offline),
                snap.max_rounds(Phase::Online),
            )
        };
        prop_assert!(run(xs1) == run(xs2), "cost leaked input dependence");
        Ok(())
    });
}
