//! Replica fleet serving (DESIGN.md §Replica fleet): the router must
//! hand out sticky least-pressure assignments, keep the fleet available
//! across a replica loss (only the lost replica's clients are
//! affected), refuse symmetrically when NO replica is healthy, fail
//! loudly on topology divergence, and never perturb logits — every
//! routed request must match an in-process replay bit-for-bit. The
//! adaptive prep scheduler must reach zero request-path offline bytes
//! on a pressured key without any hand-set static `--prep` budget.

use std::net::TcpListener;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use ppq_bert::bench_harness::prepared_model;
use ppq_bert::coordinator::fleet::{
    fleet_session_id, halt_fleet, replica_session_id, run_fleet_router, FleetClient, FleetOpts,
    ReplicaSpec,
};
use ppq_bert::coordinator::remote::{
    run_party, seed_from_label, served_keys, Completed, InferenceRequest, PartyOpts, RemoteClient,
    ServeOpts,
};
use ppq_bert::coordinator::Session;
use ppq_bert::core::error::Result;
use ppq_bert::model::config::{BertConfig, TaskKind};
use ppq_bert::model::weights::synth_input;
use ppq_bert::party::SessionCfg;
use ppq_bert::protocols::max::MaxStrategy;

/// Spawn one replica trio (real loopback sockets, one thread per party
/// process body) under its fleet label: the label fixes the master
/// seed, exactly as `repro party --session LABEL` does.
fn spawn_replica(
    cfg: BertConfig,
    serve: &ServeOpts,
    label: &str,
) -> ([String; 3], Vec<JoinHandle<Result<()>>>) {
    let listeners: Vec<TcpListener> =
        (0..3).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
    let addrs: [String; 3] = listeners
        .iter()
        .map(|l| l.local_addr().unwrap().to_string())
        .collect::<Vec<_>>()
        .try_into()
        .unwrap();
    let mut handles = Vec::new();
    for (id, listener) in listeners.into_iter().enumerate() {
        let mut opts = PartyOpts::new(id, cfg);
        opts.serve = serve.clone();
        opts.scfg.master_seed = seed_from_label(label);
        for p in 0..3 {
            if p != id {
                opts.peers[p] = Some(addrs[p].clone());
            }
        }
        handles.push(std::thread::spawn(move || run_party(listener, opts)));
    }
    (addrs, handles)
}

/// Spawn a router over the given replicas; returns its address and the
/// router thread handle.
fn spawn_router(
    cfg: BertConfig,
    serve: &ServeOpts,
    replicas: Vec<ReplicaSpec>,
) -> (String, JoinHandle<Result<()>>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = FleetOpts {
        replicas,
        cfg,
        keys: served_keys(serve, &cfg),
        poll: Duration::from_millis(100),
        timeout: Duration::from_secs(10),
    };
    let handle = std::thread::spawn(move || run_fleet_router(listener, opts));
    (addr, handle)
}

/// Sticky least-pressure assignment + the fleet's bit-identity pin:
/// four clients spread 2/2 across two replicas (each holds its router
/// connection, so the router's live-connection count alternates the
/// picks), every request is served by the client's assigned trio, and
/// an in-process replay of each replica's window stream — seeded from
/// that replica's label — matches every logit bit-for-bit. One fleet
/// halt through the router then drains both trios and the router.
#[test]
fn fleet_spreads_sticky_assignments_and_matches_in_process_replay() {
    let cfg = BertConfig::tiny();
    // One-request windows: every pool key is (fingerprint, 1), so the
    // warm-window invariant is exact (see DESIGN.md §Replica fleet).
    let serve = ServeOpts { max_batch: 1, ..ServeOpts::default() };
    let (addrs0, handles0) = spawn_replica(cfg, &serve, "fleet-r0");
    let (addrs1, handles1) = spawn_replica(cfg, &serve, "fleet-r1");
    let keys = served_keys(&serve, &cfg);
    let (router, router_handle) = spawn_router(
        cfg,
        &serve,
        vec![
            ReplicaSpec { label: "fleet-r0".into(), addrs: addrs0 },
            ReplicaSpec { label: "fleet-r1".into(), addrs: addrs1 },
        ],
    );

    // Sequential connects (each client keeps its router connection
    // open) make the least-pressure picks deterministic: 0, 1, 0, 1.
    let mut clients: Vec<FleetClient> = (0..4)
        .map(|k| {
            FleetClient::connect(&router, &cfg, &keys, Duration::from_secs(30))
                .unwrap_or_else(|e| panic!("client {k}: {e}"))
        })
        .collect();
    let assigned: Vec<u32> = clients.iter().map(|c| c.assign.replica).collect();
    assert_eq!(assigned, vec![0, 1, 0, 1], "least-pressure must alternate idle replicas");
    for c in &clients {
        let expect = if c.assign.replica == 0 { "fleet-r0" } else { "fleet-r1" };
        assert_eq!(c.assign.label, expect);
    }

    // Each client drives its assigned trio; requests stay on that
    // replica (stickiness is the connection itself).
    let mut done: Vec<(u32, usize, Completed)> = Vec::new();
    for round in 0..2 {
        for (k, fc) in clients.iter_mut().enumerate() {
            let ridx = round * 4 + k;
            let req = InferenceRequest::new(TaskKind::Classify, cfg.seq_len, input(&cfg, ridx));
            let resp = fc.client.infer_request(&req).expect("serve");
            done.push((fc.assign.replica, ridx, resp.completed));
        }
    }

    // Replay each replica's observed window stream through an
    // in-process session seeded from ITS label: logits must be
    // bit-identical — the fleet changes where a request runs, never
    // what it computes. (A single-trio deployment replays against the
    // same in-process baseline, so fleet == single-trio bit-for-bit.)
    for replica in [0u32, 1] {
        let label = if replica == 0 { "fleet-r0" } else { "fleet-r1" };
        let mut mine: Vec<&(u32, usize, Completed)> =
            done.iter().filter(|(r, _, _)| *r == replica).collect();
        assert_eq!(mine.len(), 4, "2 clients x 2 rounds per replica");
        mine.sort_by_key(|(_, _, c)| (c.wid(), c.pos()));
        let scfg = SessionCfg { master_seed: seed_from_label(label), ..SessionCfg::default() };
        let (w, _) = prepared_model(cfg);
        let sess = Session::start(cfg, w, scfg, MaxStrategy::Tournament);
        for (_, ridx, c) in mine {
            assert_eq!(c.batch(), 1, "max_batch 1 serves one-request windows");
            let replay = sess.infer_batch(&[input(&cfg, *ridx)]);
            assert_eq!(c.logits, replay[0], "request {ridx} on replica {replica}");
        }
        sess.shutdown();
    }

    drop(clients);
    halt_fleet(&router, &cfg, &keys, Duration::from_secs(30)).expect("fleet halt");
    router_handle.join().expect("router thread").expect("router exits cleanly");
    for h in handles0.into_iter().chain(handles1) {
        h.join().expect("party thread").expect("party exits cleanly");
    }
}

/// Losing one replica must only affect that replica's clients: the
/// fleet keeps admitting (new connections land on the survivor), a
/// survivor-assigned client keeps serving, and once the LAST replica is
/// gone the router refuses symmetrically with a clean error instead of
/// handing out dead trios.
#[test]
fn replica_loss_reroutes_new_clients_and_empty_fleet_refuses() {
    let cfg = BertConfig::tiny();
    let serve = ServeOpts { max_batch: 1, ..ServeOpts::default() };
    let (addrs0, handles0) = spawn_replica(cfg, &serve, "fleet-r0");
    let (addrs1, handles1) = spawn_replica(cfg, &serve, "fleet-r1");
    let keys = served_keys(&serve, &cfg);
    let (router, router_handle) = spawn_router(
        cfg,
        &serve,
        vec![
            ReplicaSpec { label: "fleet-r0".into(), addrs: addrs0.clone() },
            ReplicaSpec { label: "fleet-r1".into(), addrs: addrs1.clone() },
        ],
    );

    let mut a = FleetClient::connect(&router, &cfg, &keys, Duration::from_secs(30)).expect("a");
    let mut b = FleetClient::connect(&router, &cfg, &keys, Duration::from_secs(30)).expect("b");
    assert_eq!((a.assign.replica, b.assign.replica), (0, 1));

    // Take replica 0 down (a clean drain stands in for the smoke
    // test's kill -9: either way its listener goes away and the
    // router's poller loses the stats link).
    let r0_session = replica_session_id("fleet-r0", &cfg, &keys);
    RemoteClient::connect(&addrs0, r0_session, Duration::from_secs(30))
        .expect("halt probe")
        .shutdown()
        .expect("drain replica 0");
    for h in handles0 {
        h.join().expect("party thread").expect("replica 0 exits cleanly");
    }

    // The survivor's client never noticed.
    let req = InferenceRequest::new(TaskKind::Classify, cfg.seq_len, input(&cfg, 100));
    let resp = b.client.infer_request(&req).expect("survivor keeps serving");
    assert_eq!(resp.completed.batch(), 1);

    // New connections land on the survivor as soon as the poller
    // notices (bounded by the poll interval; retry with a short dial
    // budget until then).
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut c = loop {
        match FleetClient::connect(&router, &cfg, &keys, Duration::from_millis(500)) {
            Ok(fc) if fc.assign.replica == 1 => break fc,
            Ok(_) | Err(_) if Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(100));
            }
            Ok(fc) => panic!("router kept assigning dead replica {}", fc.assign.replica),
            Err(e) => panic!("router never rerouted to the survivor: {e}"),
        }
    };
    let req = InferenceRequest::new(TaskKind::Classify, cfg.seq_len, input(&cfg, 101));
    c.client.infer_request(&req).expect("rerouted client serves");

    // Down the survivor too: the fleet must refuse symmetrically.
    drop(b);
    drop(c);
    let r1_session = replica_session_id("fleet-r1", &cfg, &keys);
    RemoteClient::connect(&addrs1, r1_session, Duration::from_secs(30))
        .expect("halt probe")
        .shutdown()
        .expect("drain replica 1");
    for h in handles1 {
        h.join().expect("party thread").expect("replica 1 exits cleanly");
    }
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        match FleetClient::connect(&router, &cfg, &keys, Duration::from_millis(500)) {
            Err(e) if e.to_string().contains("no healthy replica") => break,
            Err(e) if Instant::now() >= deadline => panic!("wrong refusal: {e}"),
            Ok(_) if Instant::now() >= deadline => panic!("empty fleet still assigning"),
            _ => std::thread::sleep(Duration::from_millis(100)),
        }
    }

    // `a` was the dead replica's client: its trio is gone, so its next
    // request errors — locally, without poisoning anything above.
    let req = InferenceRequest::new(TaskKind::Classify, cfg.seq_len, input(&cfg, 102));
    assert!(a.client.infer_request(&req).is_err(), "dead replica's client must fail");

    halt_fleet(&router, &cfg, &keys, Duration::from_secs(30)).expect("fleet halt");
    router_handle.join().expect("router thread").expect("router exits cleanly");
}

/// The adaptive prep scheduler (zero static `--prep`): under a skewed
/// mix the pressured key's EWMA share grows its pool target, so after a
/// short warm-up every window on that key is served from ahead-of-time
/// material — zero request-path offline bytes — while the idle key is
/// never prepped past the floor (0).
#[test]
fn adaptive_prep_reaches_zero_offline_bytes_on_the_pressured_key() {
    let cfg = BertConfig::tiny();
    let serve = ServeOpts {
        max_batch: 1,
        prep_depth: 0,
        prep_adaptive: true,
        prep_ceiling: 4,
        buckets: vec![4, cfg.seq_len],
        ..ServeOpts::default()
    };
    let (addrs, handles) = spawn_replica(cfg, &serve, "fleet-r0");
    let keys = served_keys(&serve, &cfg);
    let session = replica_session_id("fleet-r0", &cfg, &keys);
    let mut client = RemoteClient::connect(&addrs, session, Duration::from_secs(30)).expect("c");

    // Pressure ONLY the full-length bucket. Sequential submit/wait
    // leaves the sequencer idle between windows, which is when the
    // adaptive scheduler banks tapes for the hot key.
    let mut offline = Vec::new();
    for i in 0..8usize {
        let req = InferenceRequest::new(TaskKind::Classify, cfg.seq_len, input(&cfg, 200 + i));
        let resp = client.infer_request(&req).expect("serve");
        offline.push(resp.completed.window_offline_bytes());
        // Give the idle-prep loop room to top the pool back up.
        std::thread::sleep(Duration::from_millis(150));
    }
    assert!(offline[0] > 0, "the very first window has nothing banked (floor is 0)");
    assert_eq!(
        offline[4..],
        [0, 0, 0, 0],
        "sustained pressure must converge to warm (zero-offline-byte) windows: {offline:?}"
    );

    let stats = client.stats(1).expect("stats");
    assert!(stats.preps > 0, "the scheduler must have banked tapes");
    // The idle bucket's share decays to 0, so its target stays at the
    // floor: nothing pooled beyond the hot key's ceiling.
    assert!(
        stats.tapes <= 4,
        "only the pressured key may hold tapes (ceiling 4), got {}",
        stats.tapes
    );

    client.shutdown().expect("drain");
    for h in handles {
        h.join().expect("party thread").expect("party exits cleanly");
    }
}

/// Topology divergence must fail loudly at connect time, in both
/// directions: a replica serving a different (task, bucket) set than
/// the router claims never becomes healthy (its topology-bound session
/// id fails the poller's handshake, so clients are refused, not handed
/// a diverged trio); and a CLIENT whose topology differs from the
/// router's is rejected at the fleet handshake by the session echo.
#[test]
fn topology_divergence_is_loud_at_connect_time() {
    let cfg = BertConfig::tiny();
    // The replica really serves only the full-length bucket...
    let real = ServeOpts { max_batch: 1, ..ServeOpts::default() };
    let (addrs, handles) = spawn_replica(cfg, &real, "fleet-r0");
    // ...but the router (and its clients) believe the fleet serves two.
    let claimed = ServeOpts { max_batch: 1, buckets: vec![4, cfg.seq_len], ..ServeOpts::default() };
    let claimed_keys = served_keys(&claimed, &cfg);
    let (router, router_handle) = spawn_router(
        cfg,
        &claimed,
        vec![ReplicaSpec { label: "fleet-r0".into(), addrs: addrs.clone() }],
    );

    // The diverged replica can never pass the poller's session check,
    // so the fleet has no healthy replica to assign.
    let err = FleetClient::connect(&router, &cfg, &claimed_keys, Duration::from_secs(10))
        .expect_err("a diverged replica must not be assigned");
    assert!(
        err.to_string().contains("no healthy replica"),
        "expected the symmetric refusal, got: {err}"
    );

    // A client on a third topology disagrees with the ROUTER's session
    // id and is rejected by the handshake echo itself.
    let other = ServeOpts { max_batch: 1, buckets: vec![4], ..ServeOpts::default() };
    let other_keys = served_keys(&other, &cfg);
    assert_ne!(fleet_session_id(&cfg, &other_keys), fleet_session_id(&cfg, &claimed_keys));
    let err = FleetClient::connect(&router, &cfg, &other_keys, Duration::from_secs(10))
        .expect_err("a diverged client must be rejected");
    assert!(
        err.to_string().contains("session mismatch"),
        "expected a session-mismatch rejection, got: {err}"
    );

    // The replica itself is still a perfectly healthy SINGLE-TRIO
    // deployment under its true topology.
    let real_keys = served_keys(&real, &cfg);
    let session = replica_session_id("fleet-r0", &cfg, &real_keys);
    let mut client = RemoteClient::connect(&addrs, session, Duration::from_secs(30)).expect("c");
    let req = InferenceRequest::new(TaskKind::Classify, cfg.seq_len, input(&cfg, 300));
    client.infer_request(&req).expect("true-topology client serves");

    // Fleet halt only drains HEALTHY replicas — the diverged trio was
    // never healthy, so drain it directly under its true session.
    halt_fleet(&router, &cfg, &claimed_keys, Duration::from_secs(30)).expect("fleet halt");
    router_handle.join().expect("router thread").expect("router exits cleanly");
    client.shutdown().expect("drain the diverged replica");
    for h in handles {
        h.join().expect("party thread").expect("party exits cleanly");
    }
}

/// Deterministic per-request input (mirrors `repro loadgen`'s stream).
fn input(cfg: &BertConfig, ridx: usize) -> Vec<i64> {
    synth_input(cfg, 100 + ridx as u64)
}
