//! Coordinator/serving-layer integration tests: session reuse, batching,
//! metrics accounting, failure handling.

use ppq_bert::bench_harness::prepared_model;
use ppq_bert::coordinator::{Coordinator, ServerConfig};
use ppq_bert::model::config::BertConfig;
use ppq_bert::model::weights::synth_input;
use ppq_bert::transport::{NetParams, Phase};

fn tiny_server(max_batch: usize) -> Coordinator {
    let cfg = BertConfig::tiny();
    let (w, _) = prepared_model(cfg);
    let mut sc = ServerConfig::new(cfg);
    sc.max_batch = max_batch;
    Coordinator::start(sc, w)
}

#[test]
fn serves_queue_in_fifo_order() {
    let cfg = BertConfig::tiny();
    let mut coord = tiny_server(8);
    let ids: Vec<u64> = (0..5)
        .map(|i| coord.submit(synth_input(&cfg, 50 + i)))
        .collect();
    let results = coord.run_batch();
    assert_eq!(results.len(), 5);
    assert_eq!(results.iter().map(|r| r.id).collect::<Vec<_>>(), ids);
    assert_eq!(coord.pending(), 0);
    assert_eq!(coord.completed(), 5);
    coord.shutdown();
}

#[test]
fn batch_window_limits_drain() {
    let cfg = BertConfig::tiny();
    let mut coord = tiny_server(2);
    for i in 0..5 {
        coord.submit(synth_input(&cfg, i));
    }
    assert_eq!(coord.run_batch().len(), 2);
    assert_eq!(coord.pending(), 3);
    assert_eq!(coord.run_batch().len(), 2);
    assert_eq!(coord.run_batch().len(), 1);
    assert_eq!(coord.run_batch().len(), 0);
    coord.shutdown();
}

#[test]
fn per_request_metrics_are_deltas() {
    let cfg = BertConfig::tiny();
    let mut coord = tiny_server(8);
    coord.submit(synth_input(&cfg, 1));
    coord.submit(synth_input(&cfg, 2));
    let results = coord.run_batch();
    // Each request pays roughly the same online bytes; neither includes
    // the one-time setup.
    let (a, b) = (&results[0], &results[1]);
    assert!(a.online_bytes > 0 && b.online_bytes > 0);
    let ratio = a.online_bytes as f64 / b.online_bytes as f64;
    assert!((0.8..1.25).contains(&ratio), "{ratio}");
    assert!(a.offline_bytes > a.online_bytes); // offline dominates per request
    coord.shutdown();
}

#[test]
fn modeled_latency_orders_lan_below_wan() {
    let cfg = BertConfig::tiny();
    let (w, x) = prepared_model(cfg);
    let run = |net: NetParams| {
        let mut sc = ServerConfig::new(cfg);
        sc.net = net;
        let (w2, x2) = (
            ppq_bert::model::weights::Weights {
                cfg,
                tensors: w.tensors.clone(),
                scales: w.scales.clone(),
            },
            x.clone(),
        );
        let mut coord = Coordinator::start(sc, w2);
        coord.submit(x2);
        let r = coord.run_batch().remove(0);
        coord.shutdown();
        r
    };
    let lan = run(NetParams::LAN);
    let wan = run(NetParams::WAN);
    assert!(wan.online_modeled > lan.online_modeled * 5,
            "wan {:?} lan {:?}", wan.online_modeled, lan.online_modeled);
}

#[test]
fn metrics_report_is_populated() {
    let cfg = BertConfig::tiny();
    let mut coord = tiny_server(8);
    coord.submit(synth_input(&cfg, 3));
    coord.run_batch();
    let report = coord.metrics_report();
    assert!(report.contains("completed=1"), "{report}");
    let snap = coord.snapshot();
    assert!(snap.total_bytes(Phase::Setup) > 0);
    assert!(snap.max_rounds(Phase::Online) > 0);
    coord.shutdown();
}

#[test]
#[should_panic(expected = "assertion")]
fn rejects_wrong_input_shape() {
    let mut coord = tiny_server(8);
    coord.submit(vec![0i64; 3]); // wrong length
}
