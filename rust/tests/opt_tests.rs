//! Differential tests pinning the graph optimizer
//! (DESIGN.md §Graph optimizer): over random secure graphs and the real
//! builders, sealing
//! with `--opt 1` must change ONLY message boundaries — logits and
//! hidden shares stay bit-identical, metered online rounds drop (never
//! rise), offline bytes are unchanged, and correlation dedup batches
//! the offline correction messages without touching their content.
//!
//! Every random-graph case is generated from a `testing::Gen` seed, so
//! a failure report names the seed that replays it.

use ppq_bert::bench_harness::{prepared_inputs, prepared_model};
use ppq_bert::coordinator::session::{prep_into_pool, serve_window, CorrPool};
use ppq_bert::model::config::{BertConfig, TaskKind};
use ppq_bert::model::passes::OptConfig;
use ppq_bert::model::randgraph::{rand_graph, rand_graph_dry, rand_inputs};
use ppq_bert::model::secure::{secure_infer_batch, GraphSpec};
use ppq_bert::party::{run_3pc, SessionCfg, P0, P1};
use ppq_bert::prop_assert;
use ppq_bert::protocols::prep::{run_plan, run_plan_deduped, CorrKind, CorrShape, Correlation};
use ppq_bert::protocols::tape_store::{TapePool, TapeStore};
use ppq_bert::testing::check;
use ppq_bert::transport::Phase;

/// One fresh 3-party session evaluating random graph `seed` at `opt`:
/// P1's revealed logits, every party's hidden share vector, and the
/// session meter. Same master seed at every opt level, so any output
/// difference is the optimizer's fault.
struct RandRun {
    logits: Vec<Vec<i64>>,
    hidden: [Vec<u64>; 3],
    online_rounds: u64,
    offline_bytes: u64,
    packed_groups: usize,
}

fn run_rand(seed: u64, batch: usize, opt: OptConfig) -> RandRun {
    let inputs = rand_inputs(seed, batch);
    let (outs, snap) = run_3pc(SessionCfg::default(), move |ctx| {
        let g = rand_graph(ctx, seed, opt);
        let (logits, hidden) =
            secure_infer_batch(ctx, &g, batch, if ctx.id == P1 { Some(&inputs) } else { None });
        assert_eq!(ctx.corr_pending(), 0, "seed {seed}: tape left behind");
        (logits, hidden.vals, g.packed_groups())
    });
    let packed_groups = outs[0].2;
    let [o0, o1, o2] = outs;
    RandRun {
        logits: o1.0,
        hidden: [o0.1, o1.1, o2.1],
        online_rounds: snap.max_rounds(Phase::Online),
        offline_bytes: snap.total_bytes(Phase::Offline),
        packed_groups,
    }
}

/// The headline differential property, 50 random graphs per CI run:
/// `--opt 1` output is bit-identical to `--opt 0` (logits AND every
/// party's hidden shares), online rounds never rise — and drop strictly
/// whenever the packing pass fused anything — while offline bytes are
/// untouched. Failures report the generator seed for replay.
#[test]
fn opt1_is_bit_identical_and_never_slower_over_random_graphs() {
    check("opt differential over random graphs", 50, |g| {
        let seed = g.seed;
        let batch = if seed % 2 == 0 { 1 } else { 4 };
        let base = run_rand(seed, batch, OptConfig::none());
        let opt = run_rand(seed, batch, OptConfig::o1());
        prop_assert!(
            opt.logits == base.logits,
            "B={batch}: logits diverged: opt1 {:?} vs opt0 {:?}",
            opt.logits,
            base.logits
        );
        for p in 0..3 {
            prop_assert!(
                opt.hidden[p] == base.hidden[p],
                "B={batch}: party {p} hidden shares diverged"
            );
        }
        prop_assert!(
            opt.online_rounds <= base.online_rounds,
            "B={batch}: opt1 used MORE online rounds ({} vs {})",
            opt.online_rounds,
            base.online_rounds
        );
        prop_assert!(
            opt.packed_groups == 0 || opt.online_rounds < base.online_rounds,
            "B={batch}: {} packed groups saved no rounds ({} vs {})",
            opt.packed_groups,
            opt.online_rounds,
            base.online_rounds
        );
        prop_assert!(
            opt.offline_bytes == base.offline_bytes,
            "B={batch}: offline bytes changed: {} vs {}",
            opt.offline_bytes,
            base.offline_bytes
        );
        Ok(())
    });
}

/// One fresh BERT-tiny session at `opt`: P1 logits, per-party hidden
/// shares, metered online rounds and packed-group count.
fn run_bert(opt: OptConfig, batch: usize) -> RandRun {
    let cfg = BertConfig::tiny();
    let (w, _) = prepared_model(cfg);
    let inputs = prepared_inputs(&cfg, batch);
    let (outs, snap) = run_3pc(SessionCfg::default(), move |ctx| {
        let weights = if ctx.id == P0 { Some(&w) } else { None };
        let g = GraphSpec::new(TaskKind::Classify, cfg).with_opt(opt).build(ctx, weights);
        let (logits, hidden) =
            secure_infer_batch(ctx, &g, batch, if ctx.id == P1 { Some(&inputs) } else { None });
        (logits, hidden.vals, g.packed_groups())
    });
    let packed_groups = outs[0].2;
    let [o0, o1, o2] = outs;
    RandRun {
        logits: o1.0,
        hidden: [o0.1, o1.1, o2.1],
        online_rounds: snap.max_rounds(Phase::Online),
        offline_bytes: snap.total_bytes(Phase::Offline),
        packed_groups,
    }
}

/// The acceptance measurement: BERT-tiny's MEASURED online round count
/// at `--opt 1` is strictly below the `--opt 0` per-op sum (B = 1 and
/// B = 4), with bit-identical logits and hidden shares. The attention
/// blocks' adjacent conversion pairs must actually fuse.
#[test]
fn bert_tiny_opt1_measures_strictly_fewer_online_rounds() {
    for batch in [1usize, 4] {
        let base = run_bert(OptConfig::none(), batch);
        let opt = run_bert(OptConfig::o1(), batch);
        assert_eq!(opt.logits, base.logits, "B={batch}: logits must be bit-identical");
        for p in 0..3 {
            assert_eq!(opt.hidden[p], base.hidden[p], "B={batch}: party {p} hidden shares");
        }
        assert_eq!(base.packed_groups, 0, "B={batch}: opt0 must stay unpacked");
        let layers = BertConfig::tiny().n_layers;
        assert_eq!(
            opt.packed_groups,
            2 * layers,
            "B={batch}: each layer's two attention conversion pairs must fuse"
        );
        assert!(
            opt.online_rounds < base.online_rounds,
            "B={batch}: measured opt1 rounds {} must be strictly below the opt0 \
             per-op sum {}",
            opt.online_rounds,
            base.online_rounds
        );
        assert_eq!(opt.offline_bytes, base.offline_bytes, "B={batch}: offline bytes");
    }
}

/// Correlation dedup is draw-identical: executing the same plan with
/// [`run_plan`] and [`run_plan_deduped`] (fresh sessions, same master
/// seed) yields field-for-field EQUAL correlation tapes at every party,
/// the same offline byte total, and strictly fewer offline rounds —
/// message boundaries are the only thing that moved.
#[test]
fn deduped_plan_run_is_field_identical_and_batches_messages() {
    let cfg = BertConfig::tiny();
    let g = GraphSpec::new(TaskKind::Classify, cfg).dry();
    let plan_a = g.plan(2);
    let plan_b = g.plan(2);
    let plan_len = plan_a.len();
    let (tapes_a, snap_a) = run_3pc(SessionCfg::default(), move |ctx| run_plan(ctx, &plan_a));
    let (tapes_b, snap_b) =
        run_3pc(SessionCfg::default(), move |ctx| run_plan_deduped(ctx, &plan_b));
    for (p, (a, b)) in tapes_a.iter().zip(&tapes_b).enumerate() {
        assert_eq!(a.len(), plan_len, "party {p}: tape length");
        assert_eq!(a, &b.0, "party {p}: correlations must be field-identical under dedup");
    }
    let stats = &tapes_b[0].1;
    assert_eq!(stats.ops(), plan_len, "dedup stats must cover the whole plan");
    assert!(
        stats.messages_deduped() < stats.messages_unopt,
        "repeated layer shapes must batch ({} -> {})",
        stats.messages_unopt,
        stats.messages_deduped()
    );
    assert_eq!(
        snap_a.total_bytes(Phase::Offline),
        snap_b.total_bytes(Phase::Offline),
        "dedup must not change offline bytes"
    );
    assert!(
        snap_b.max_rounds(Phase::Offline) < snap_a.max_rounds(Phase::Offline),
        "dedup must reduce offline rounds ({} vs {})",
        snap_b.max_rounds(Phase::Offline),
        snap_a.max_rounds(Phase::Offline)
    );
}

/// Offline tapes are thread-invariant: executing the same plan (plain
/// AND deduped) under worker pools of 1, 2, 4 and 8 threads yields
/// field-identical correlation tapes at every party and identical
/// offline byte/message/round meters — the parallel PRG draws are
/// position-addressed into the same keystream, so thread count never
/// reaches the tape (DESIGN.md §Parallel runtime).
#[test]
fn offline_tape_is_bit_identical_across_thread_counts() {
    let cfg = BertConfig::tiny();
    let g = GraphSpec::new(TaskKind::Classify, cfg).dry();
    let run = |threads: usize, dedup: bool| {
        let plan = g.plan(2);
        let scfg = SessionCfg { threads, ..SessionCfg::default() };
        run_3pc(scfg, move |ctx| {
            if dedup {
                run_plan_deduped(ctx, &plan).0
            } else {
                run_plan(ctx, &plan)
            }
        })
    };
    for dedup in [false, true] {
        let (want, want_snap) = run(1, dedup);
        for threads in [2usize, 4, 8] {
            let (got, snap) = run(threads, dedup);
            for p in 0..3 {
                assert_eq!(got[p], want[p], "dedup={dedup} T={threads}: party {p} tape");
            }
            assert_eq!(snap.bytes, want_snap.bytes, "dedup={dedup} T={threads}: bytes");
            assert_eq!(snap.msgs, want_snap.msgs, "dedup={dedup} T={threads}: msgs");
            assert_eq!(snap.rounds, want_snap.rounds, "dedup={dedup} T={threads}: rounds");
        }
    }
}

/// Tapes never cross opt levels: the fingerprint (pool key) differs, a
/// window served with the `--opt 1` graph leaves an `--opt 0` tape
/// untouched (cold fallback, counted as misses), and the `--opt 0`
/// graph still consumes its own tape warm afterwards.
#[test]
fn opt_levels_never_share_pool_keys() {
    let seed = 7u64;
    let inputs = rand_inputs(seed, 1);
    let (outs, snap) = run_3pc(SessionCfg::default(), move |ctx| {
        let g0 = rand_graph(ctx, seed, OptConfig::none());
        let g1 = rand_graph(ctx, seed, OptConfig::o1());
        assert_ne!(g0.fingerprint(), g1.fingerprint(), "opt must re-key the pool");
        let mut pool = CorrPool::new();
        prep_into_pool(ctx, &g0, &mut pool, 1);
        let key0 = (g0.fingerprint(), 1usize);
        assert_eq!(pool.get(&key0).map_or(0, |q| q.len()), 1);
        let p1_inputs = if ctx.id == P1 { Some(&inputs[..]) } else { None };
        // Serving the opt1 graph must NOT consume the opt0 tape.
        let _ = serve_window(ctx, &g1, &mut pool, 1, p1_inputs);
        assert_eq!(
            pool.get(&key0).map_or(0, |q| q.len()),
            1,
            "an opt1 window consumed an opt0 tape"
        );
        // The opt0 graph still serves its own tape warm.
        let _ = serve_window(ctx, &g0, &mut pool, 1, p1_inputs);
        assert_eq!(pool.get(&key0).map_or(0, |q| q.len()), 0, "warm window must pop its tape");
        (g0.plan(1).len(), g1.plan(1).len())
    });
    let (warm_len, cold_len) = outs[1];
    assert_eq!(snap.pool_hits(), warm_len as u64, "opt0 window must be fully warm");
    assert_eq!(snap.pool_misses(), cold_len as u64, "opt1 window must be fully cold");
}

/// The durable store keeps the opt-keyed pools separate across a
/// restart: tapes persisted under the `--opt 0` fingerprint reload
/// under that key only — a party restarted at `--opt 1` finds its pool
/// empty instead of a foreign tape.
#[test]
fn tape_store_restart_keeps_opt_keys_separate() {
    let dir = std::env::temp_dir().join(format!("ppq_opt_tape_keys_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let fp0 = rand_graph_dry(3, OptConfig::none()).fingerprint();
    let fp1 = rand_graph_dry(3, OptConfig::o1()).fingerprint();
    assert_ne!(fp0, fp1);

    // Geometry-consistent synthetic tape (the codec round-trips
    // geometry; content is opaque filler), persisted under the opt0 key.
    let shape = CorrShape {
        kind: CorrKind::Lut1,
        x_bits: 4,
        y_bits: 0,
        out_bits: vec![16],
        n: 2,
        groups: 0,
    };
    let corr = Correlation {
        shape: shape.clone(),
        tsh: vec![(0..2 * 16).map(|i| i as u64).collect()],
        dx: vec![9, 11],
        dy: Vec::new(),
    };
    let session = [7u8; 16];
    let store = TapeStore::new(&dir, 2, session).expect("create store");
    let mut pool = TapePool::new();
    pool.entry((fp0, 1)).or_default().push_back(vec![corr.clone()]);
    store.save_pool(&pool).expect("persist pool");
    drop(store);

    // Restart: the reloaded pool serves the opt0 key and ONLY that key.
    let store = TapeStore::new(&dir, 2, session).expect("reopen store");
    let (loaded, warnings) = store.load_pool();
    assert!(warnings.is_empty(), "clean store must reload without warnings: {warnings:?}");
    assert_eq!(loaded.get(&(fp0, 1)).map_or(0, |q| q.len()), 1);
    assert!(!loaded.contains_key(&(fp1, 1)), "opt1 key must not inherit opt0 tapes");
    assert_eq!(loaded[&(fp0, 1)][0], vec![corr], "tape content must round-trip");
    let _ = std::fs::remove_dir_all(&dir);
}
