//! End-to-end parity: the op-graph walk must be BIT-IDENTICAL to the
//! pre-refactor hand-written `secure_infer_batch` pipeline — same
//! logits, same hidden shares at every party, same per-phase meter.
//!
//! The reference below is a frozen, line-for-line copy of the
//! pre-graph `model/secure.rs` pipeline (setup + layer + batched
//! inference). It is deliberately NOT shared with the library: it is
//! the oracle the refactor is pinned against. Both sides run under the
//! same master seed, so every PRG draw and every protocol message must
//! line up for the outputs to match exactly.

use ppq_bert::bench_harness::{prepared_inputs, prepared_model};
use ppq_bert::core::ring::{R16, R4};
use ppq_bert::model::config::{BertConfig, TaskKind};
use ppq_bert::model::secure::{secure_infer_batch, GraphSpec};
use ppq_bert::model::weights::Weights;
use ppq_bert::party::{run_3pc, PartyCtx, SessionCfg, P0, P1};
use ppq_bert::protocols::convert::{convert_to_rss, extend_ring_many};
use ppq_bert::protocols::layernorm::{layernorm_rows, LnParams};
use ppq_bert::protocols::lut::{lut_eval, LutTable};
use ppq_bert::protocols::matmul::{
    rss_matmul_full, rss_matmul_trc, rss_matmul_trc_multi, rss_matmul_trc_seq,
};
use ppq_bert::protocols::max::MaxStrategy;
use ppq_bert::protocols::relu::relu_to_rss16;
use ppq_bert::protocols::softmax::{softmax_rows, SoftmaxTables};
use ppq_bert::protocols::tables::{ln_div_table, relu16_table};
use ppq_bert::sharing::additive::{reveal2, share2};
use ppq_bert::sharing::rss::{reshare_a2_to_rss, share_rss};
use ppq_bert::sharing::{A2, Rss};
use ppq_bert::transport::{Phase, PHASES};

// ---------------------------------------------------------------------------
// Frozen pre-refactor reference pipeline (do not "fix" or share this).

struct RefLayer {
    wq: Rss,
    wk: Rss,
    wv: Rss,
    wo: Rss,
    w1: Rss,
    w2: Rss,
    ln1: LnParams,
    ln2: LnParams,
    conv_att: LutTable,
    conv_av: LutTable,
}

struct RefBert {
    cfg: BertConfig,
    max_strategy: MaxStrategy,
    layers: Vec<RefLayer>,
    cls_w: Rss,
    sm: SoftmaxTables,
}

fn share_scaled_sign(
    ctx: &PartyCtx,
    w: Option<&Weights>,
    name: &str,
    scale_name: &str,
    shape_hint: (usize, usize),
) -> Rss {
    let len = shape_hint.0 * shape_hint.1;
    let vals: Option<Vec<u64>> = w.map(|w| {
        let t = w.tensor(name);
        let s = w.scale(scale_name);
        t.data.iter().map(|&v| R16.encode(v * s)).collect()
    });
    share_rss(ctx, P0, R16, vals.as_deref(), len)
}

impl RefBert {
    fn setup(ctx: &PartyCtx, cfg: BertConfig, weights: Option<&Weights>) -> RefBert {
        assert!((ctx.id == P0) == weights.is_some());
        ctx.with_phase(Phase::Setup, |ctx| {
            let d = cfg.d_model;
            let mut layers = Vec::with_capacity(cfg.n_layers);
            for li in 0..cfg.n_layers {
                let p = |n: &str| format!("layer{li}.{n}");
                let sc = |w: &Weights, n: &str| w.scale(&format!("layer{li}.s_{n}"));
                let ln = |g: &str, gs: &str, b: &str| -> LnParams {
                    let gamma_vals: Option<Vec<u64>> = weights.map(|w| {
                        let s = sc(w, gs);
                        w.tensor(&p(g)).data.iter().map(|&v| R16.encode(v * s)).collect()
                    });
                    let beta_vals: Option<Vec<u64>> = weights
                        .map(|w| w.tensor(&p(b)).data.iter().map(|&v| R4.encode(v)).collect());
                    LnParams {
                        gamma: share_rss(ctx, P0, R16, gamma_vals.as_deref(), d),
                        beta: share2(ctx, P0, R4, beta_vals.as_deref(), d),
                        table: ln_div_table(cfg.ln_sv, cfg.ln_eps),
                    }
                };
                let s_att = weights.map(|w| sc(w, "att")).unwrap_or(0);
                let s_av = weights.map(|w| sc(w, "av")).unwrap_or(0);
                layers.push(RefLayer {
                    wq: share_scaled_sign(ctx, weights, &p("wq"), &p("s_qkv"), (d, d)),
                    wk: share_scaled_sign(ctx, weights, &p("wk"), &p("s_qkv"), (d, d)),
                    wv: share_scaled_sign(ctx, weights, &p("wv"), &p("s_qkv"), (d, d)),
                    wo: share_scaled_sign(ctx, weights, &p("wo"), &p("s_o"), (d, d)),
                    w1: share_scaled_sign(ctx, weights, &p("w1"), &p("s_f1"), (cfg.d_ff, d)),
                    w2: share_scaled_sign(ctx, weights, &p("w2"), &p("s_f2"), (d, cfg.d_ff)),
                    ln1: ln("ln1_g", "g1", "ln1_b"),
                    ln2: ln("ln2_g", "g2", "ln2_b"),
                    conv_att: LutTable::from_fn(R4, R16, move |i| {
                        R16.encode(R4.decode(i) * s_att)
                    }),
                    conv_av: LutTable::from_fn(R4, R16, move |i| R16.encode(i as i64 * s_av)),
                });
            }
            let cls_vals: Option<Vec<u64>> = weights.map(|w| {
                w.tensor("cls.w")
                    .data
                    .iter()
                    .map(|&v| R16.encode(v * cfg.scale_cls))
                    .collect()
            });
            let cls_w = share_rss(ctx, P0, R16, cls_vals.as_deref(), cfg.n_classes * d);
            RefBert {
                cfg,
                max_strategy: MaxStrategy::Tournament,
                layers,
                cls_w,
                sm: SoftmaxTables::new(cfg.sm_sx),
            }
        })
    }
}

fn gather_heads(x: &A2, batch: usize, s: usize, d: usize, heads: usize, dh: usize) -> A2 {
    let len = batch * heads * s * dh;
    if x.vals.is_empty() {
        return A2::empty(x.ring, len);
    }
    let mut vals = Vec::with_capacity(len);
    for b in 0..batch {
        for hd in 0..heads {
            for r in 0..s {
                let base = (b * s + r) * d + hd * dh;
                vals.extend_from_slice(&x.vals[base..base + dh]);
            }
        }
    }
    A2 { ring: x.ring, vals, len }
}

fn scatter_heads(x: &A2, batch: usize, s: usize, d: usize, heads: usize, dh: usize) -> A2 {
    let len = batch * s * d;
    if x.vals.is_empty() {
        return A2::empty(x.ring, len);
    }
    let mut vals = vec![0u64; len];
    for b in 0..batch {
        for hd in 0..heads {
            for r in 0..s {
                let src = ((b * heads + hd) * s + r) * dh;
                let dst = (b * s + r) * d + hd * dh;
                vals[dst..dst + dh].copy_from_slice(&x.vals[src..src + dh]);
            }
        }
    }
    A2 { ring: x.ring, vals, len }
}

fn transpose_rss_blocks(x: &Rss, blocks: usize, rows: usize, cols: usize) -> Rss {
    let tr = |v: &Vec<u64>| -> Vec<u64> {
        let mut out = vec![0u64; v.len()];
        for g in 0..blocks {
            let base = g * rows * cols;
            for r in 0..rows {
                for c in 0..cols {
                    out[base + c * rows + r] = v[base + r * cols + c];
                }
            }
        }
        out
    };
    Rss { ring: x.ring, next: tr(&x.next), prev: tr(&x.prev) }
}

fn convert_via(ctx: &PartyCtx, t: &LutTable, x: &A2) -> Rss {
    let wide = lut_eval(ctx, t, x);
    reshare_a2_to_rss(ctx, &wide)
}

fn ref_layer_batch(ctx: &PartyCtx, m: &RefBert, li: usize, h4: &A2, batch: usize) -> A2 {
    let cfg = &m.cfg;
    let (s, d, dh, nh) = (cfg.seq_len, cfg.d_model, cfg.d_head(), cfg.n_heads);
    let rows = batch * s;
    let l = &m.layers[li];

    let h16 = convert_to_rss(ctx, h4, R16, true);
    let qkv = rss_matmul_trc_multi(ctx, &h16, &[&l.wq, &l.wk, &l.wv], rows, d, d, 4);
    let (q4, k4, v4) = (&qkv[0], &qkv[1], &qkv[2]);

    let qh = gather_heads(q4, batch, s, d, nh, dh);
    let kh = gather_heads(k4, batch, s, d, nh, dh);
    let vh = gather_heads(v4, batch, s, d, nh, dh);
    let blocks = batch * nh;

    let qh16 = convert_via(ctx, &l.conv_att, &qh);
    let kh16 = convert_to_rss(ctx, &kh, R16, true);
    let scores4 = rss_matmul_trc_seq(ctx, &qh16, &kh16, blocks, s, dh, s, 4);
    let attn4 = softmax_rows(ctx, &m.sm, &scores4, blocks * s, s, m.max_strategy);
    let attn16 = convert_via(ctx, &l.conv_av, &attn4);
    let vh16 = convert_to_rss(ctx, &vh, R16, true);
    let vt = transpose_rss_blocks(&vh16, blocks, s, dh);
    let ctx4 = rss_matmul_trc_seq(ctx, &attn16, &vt, blocks, s, s, dh, 4);
    let ctxcat = scatter_heads(&ctx4, batch, s, d, nh, dh);

    let ctx16 = convert_to_rss(ctx, &ctxcat, R16, true);
    let o4 = rss_matmul_trc(ctx, &ctx16, &l.wo, rows, d, d, 4);

    let ext = extend_ring_many(ctx, &[h4, &o4], R16, true);
    let res16 = ext[0].add(&ext[1]);
    let h1 = layernorm_rows(ctx, &l.ln1, &res16, rows, d);

    let h1_16 = convert_to_rss(ctx, &h1, R16, true);
    let u4 = rss_matmul_trc(ctx, &h1_16, &l.w1, rows, d, cfg.d_ff, 4);
    let relu16 = relu_to_rss16(ctx, &u4);
    let f4 = rss_matmul_trc(ctx, &relu16, &l.w2, rows, cfg.d_ff, d, 4);

    let ext2 = extend_ring_many(ctx, &[&h1, &f4], R16, true);
    let res2 = ext2[0].add(&ext2[1]);
    layernorm_rows(ctx, &l.ln2, &res2, rows, d)
}

fn ref_infer_batch(
    ctx: &PartyCtx,
    m: &RefBert,
    batch: usize,
    x4: Option<&[Vec<i64>]>,
) -> (Vec<Vec<i64>>, A2) {
    let cfg = &m.cfg;
    let (s, d) = (cfg.seq_len, cfg.d_model);
    assert!((ctx.id == P1) == x4.is_some());
    let enc: Option<Vec<u64>> = x4.map(|inputs| {
        let mut flat = Vec::with_capacity(batch * s * d);
        for x in inputs {
            flat.extend(x.iter().map(|&v| R4.encode(v)));
        }
        flat
    });
    let mut h4 = share2(ctx, P1, R4, enc.as_deref(), batch * s * d);
    for li in 0..cfg.n_layers {
        h4 = ref_layer_batch(ctx, m, li, &h4, batch);
    }
    let cls_rows: Vec<A2> = (0..batch)
        .map(|b| h4.slice(b * s * d, b * s * d + d))
        .collect();
    let cls_refs: Vec<&A2> = cls_rows.iter().collect();
    let cls_h = A2::concat(R4, &cls_refs);
    let cls16 = convert_to_rss(ctx, &cls_h, R16, true);
    let logits16 = rss_matmul_full(ctx, &cls16, &m.cls_w, batch, d, cfg.n_classes);
    let revealed = reveal2(ctx, &logits16);
    let logits: Vec<Vec<i64>> = if revealed.is_empty() {
        vec![Vec::new(); batch]
    } else {
        revealed
            .chunks(cfg.n_classes)
            .map(|c| c.iter().map(|&v| R16.decode(v)).collect())
            .collect()
    };
    (logits, h4)
}

// ---------------------------------------------------------------------------
// The parity harness.

type PartyOut = (Vec<Vec<i64>>, Vec<u64>);

fn run_reference(cfg: BertConfig, batch: usize) -> ([PartyOut; 3], Vec<(u64, u64)>) {
    let (w, _) = prepared_model(cfg);
    let inputs = prepared_inputs(&cfg, batch);
    let (outs, snap) = run_3pc(SessionCfg::default(), move |ctx| {
        let m = RefBert::setup(ctx, cfg, if ctx.id == P0 { Some(&w) } else { None });
        let (logits, h) =
            ref_infer_batch(ctx, &m, batch, if ctx.id == P1 { Some(&inputs) } else { None });
        (logits, h.vals)
    });
    let phases = PHASES.iter().map(|&p| (snap.total_bytes(p), snap.max_rounds(p))).collect();
    (outs, phases)
}

fn run_graph(cfg: BertConfig, batch: usize) -> ([PartyOut; 3], Vec<(u64, u64)>) {
    let (w, _) = prepared_model(cfg);
    let inputs = prepared_inputs(&cfg, batch);
    let (outs, snap) = run_3pc(SessionCfg::default(), move |ctx| {
        let g = GraphSpec::new(TaskKind::Classify, cfg)
            .build(ctx, if ctx.id == P0 { Some(&w) } else { None });
        let (logits, h) =
            secure_infer_batch(ctx, &g, batch, if ctx.id == P1 { Some(&inputs) } else { None });
        (logits, h.vals)
    });
    let phases = PHASES.iter().map(|&p| (snap.total_bytes(p), snap.max_rounds(p))).collect();
    (outs, phases)
}

fn assert_parity(cfg: BertConfig, batch: usize) {
    let (ref_outs, ref_phases) = run_reference(cfg, batch);
    let (g_outs, g_phases) = run_graph(cfg, batch);
    for (id, (r, g)) in ref_outs.iter().zip(&g_outs).enumerate() {
        assert_eq!(r.0, g.0, "party {id}: logits must be bit-identical");
        assert_eq!(r.1, g.1, "party {id}: hidden shares must be bit-identical");
    }
    assert_eq!(ref_phases, g_phases, "per-phase bytes/rounds must match exactly");
    // P1 and P2 hold the same opened logits; P0 learns nothing.
    assert_eq!(g_outs[1].0, g_outs[2].0);
    assert!(g_outs[0].0.iter().all(|l| l.is_empty()));
}

/// Tiny config, single request and a 2-request window.
#[test]
fn graph_matches_prerefactor_pipeline_tiny() {
    assert_parity(BertConfig::tiny(), 1);
    assert_parity(BertConfig::tiny(), 2);
}

/// BERT-base shapes (d=768, 12 heads, d_ff=3072, seq 32) at one layer:
/// exercises every base-shaped op. Ignored in debug builds (minutes of
/// unoptimized matmuls); the release smoke job runs it.
#[test]
#[cfg_attr(debug_assertions, ignore = "slow in debug; run with cargo test --release")]
fn graph_matches_prerefactor_pipeline_base_shapes() {
    assert_parity(BertConfig::base().with_layers(1), 1);
}

/// Full BERT-base. ~5 GB of in-process share material across the three
/// parties and minutes of runtime — run explicitly:
/// `cargo test --release --test graph_parity -- --ignored`
#[test]
#[ignore = "full BERT-base needs ~5 GB RSS; run explicitly with --ignored in release"]
fn graph_matches_prerefactor_pipeline_base_full() {
    assert_parity(BertConfig::base(), 1);
}
