//! Secure op-graph consistency: the SAME graph object derives the
//! offline plan and runs the online pass, so for every builder × batch
//! size × `Π_max` realization the graph-derived tape must be consumed
//! exactly — no leftovers, no inline fallbacks — and a warm (prepped)
//! window's logits must be bit-identical to a cold one's
//! (DESIGN.md §Secure op graph).

use ppq_bert::bench_harness::{prepared_inputs, prepared_model};
use ppq_bert::model::config::{BertConfig, LayerQuantConfig};
use ppq_bert::model::secure::{
    bert_classify_graph, bert_graph, bert_graph_dry, mlp_graph, mlp_graph_dry, secure_classify,
    secure_infer_batch, MlpConfig, MlpWeights,
};
use ppq_bert::party::{run_3pc, SessionCfg, P0, P1};
use ppq_bert::protocols::max::MaxStrategy;
use ppq_bert::transport::{MetricsSnapshot, Phase};

const STRATS: [MaxStrategy; 3] = [MaxStrategy::Tournament, MaxStrategy::Linear, MaxStrategy::Sort];

/// One BERT window on a fresh session: build the graph, optionally prep
/// its tape through the graph walk, evaluate, and return (P1 logits,
/// meter, plan length).
fn run_bert(
    strat: MaxStrategy,
    batch: usize,
    warm: bool,
) -> (Vec<Vec<i64>>, MetricsSnapshot, usize) {
    let cfg = BertConfig::tiny();
    let (w, _) = prepared_model(cfg);
    let inputs = prepared_inputs(&cfg, batch);
    let (outs, snap) = run_3pc(SessionCfg::default(), move |ctx| {
        let per = LayerQuantConfig::uniform(&cfg, strat);
        let g = bert_graph(ctx, &cfg, &per, if ctx.id == P0 { Some(&w) } else { None });
        let plan_len = g.plan(batch).len();
        if warm {
            let tape = g.prep(ctx, batch);
            assert_eq!(tape.len(), plan_len);
            ctx.install_corr(tape);
        }
        let (logits, _) =
            secure_infer_batch(ctx, &g, batch, if ctx.id == P1 { Some(&inputs) } else { None });
        assert_eq!(ctx.corr_pending(), 0, "tape not fully consumed (plan drift)");
        (logits, plan_len)
    });
    let (logits, plan_len) = outs[1].clone();
    (logits, snap, plan_len)
}

/// One MLP window (the non-BERT builder) on a fresh session.
fn run_mlp(batch: usize, warm: bool) -> (Vec<Vec<i64>>, MetricsSnapshot, usize) {
    let mcfg = MlpConfig::tiny();
    let inputs: Vec<Vec<i64>> = (0..batch)
        .map(|b| (0..mcfg.d_in).map(|i| ((i + 3 * b) % 15) as i64 - 7).collect())
        .collect();
    let (outs, snap) = run_3pc(SessionCfg::default(), move |ctx| {
        let mw = if ctx.id == P0 { Some(MlpWeights::synth(&mcfg, 7)) } else { None };
        let g = mlp_graph(ctx, &mcfg, mw.as_ref());
        let plan_len = g.plan(batch).len();
        if warm {
            let tape = g.prep(ctx, batch);
            assert_eq!(tape.len(), plan_len);
            ctx.install_corr(tape);
        }
        let (logits, _) =
            secure_infer_batch(ctx, &g, batch, if ctx.id == P1 { Some(&inputs) } else { None });
        assert_eq!(ctx.corr_pending(), 0, "tape not fully consumed (plan drift)");
        (logits, plan_len)
    });
    let (logits, plan_len) = outs[1].clone();
    (logits, snap, plan_len)
}

/// The headline property: every builder × batch ∈ {1, 4} × every
/// `Π_max` strategy consumes its graph-derived tape exactly (warm run:
/// hits == plan length, zero misses; cold run: misses == plan length)
/// and warm-vs-cold logits are bit-identical.
#[test]
fn plan_consistency_every_builder_batch_strategy() {
    for strat in STRATS {
        for batch in [1usize, 4] {
            let (cold_logits, cold, plan_len) = run_bert(strat, batch, false);
            let (warm_logits, warm, _) = run_bert(strat, batch, true);
            assert!(plan_len > 0);
            assert_eq!(cold.pool_misses(), plan_len as u64, "{strat:?} B={batch}: cold misses");
            assert_eq!(cold.pool_hits(), 0, "{strat:?} B={batch}");
            assert_eq!(warm.pool_hits(), plan_len as u64, "{strat:?} B={batch}: warm hits");
            assert_eq!(warm.pool_misses(), 0, "{strat:?} B={batch}: warm misses");
            assert_eq!(warm_logits, cold_logits, "{strat:?} B={batch}: warm/cold logits");
        }
    }
    for batch in [1usize, 4] {
        let (cold_logits, cold, plan_len) = run_mlp(batch, false);
        let (warm_logits, warm, _) = run_mlp(batch, true);
        assert!(plan_len > 0);
        assert_eq!(cold.pool_misses(), plan_len as u64, "mlp B={batch}: cold misses");
        assert_eq!(warm.pool_hits(), plan_len as u64, "mlp B={batch}: warm hits");
        assert_eq!(warm.pool_misses(), 0, "mlp B={batch}");
        assert_eq!(warm_logits, cold_logits, "mlp B={batch}: warm/cold logits");
    }
}

/// The dry (share-less) builder models offline cost exactly: a cold
/// window's metered `Phase::Offline` bytes equal the dry graph's
/// per-op byte accounting, summed.
#[test]
fn dry_plan_bytes_match_metered_offline_traffic() {
    for batch in [1usize, 2] {
        let (_, cold, _) = run_bert(MaxStrategy::Tournament, batch, false);
        let cfg = BertConfig::tiny();
        let g = bert_graph_dry(&cfg, &LayerQuantConfig::uniform(&cfg, MaxStrategy::Tournament));
        let modeled: u64 = g.plan_entries(batch).iter().map(|e| e.bytes).sum();
        assert_eq!(
            cold.total_bytes(Phase::Offline),
            modeled,
            "B={batch}: modeled per-op bytes must equal the metered offline traffic"
        );
    }
}

/// Fingerprints key the serving tape pools: equal for structurally
/// identical graphs (live and dry builds included), different across
/// strategies and across builders.
#[test]
fn fingerprints_track_graph_structure() {
    let cfg = BertConfig::tiny();
    let fp = |strat: MaxStrategy| {
        bert_graph_dry(&cfg, &LayerQuantConfig::uniform(&cfg, strat)).fingerprint()
    };
    assert_eq!(fp(MaxStrategy::Tournament), fp(MaxStrategy::Tournament));
    assert_ne!(fp(MaxStrategy::Tournament), fp(MaxStrategy::Sort));
    assert_ne!(fp(MaxStrategy::Tournament), fp(MaxStrategy::Linear));
    assert_ne!(fp(MaxStrategy::Tournament), mlp_graph_dry(&MlpConfig::tiny()).fingerprint());

    // The live build (with real shares) has the same structure, hence
    // the same fingerprint, as the dry build.
    let (w, _) = prepared_model(cfg);
    let (fps, _) = run_3pc(SessionCfg::default(), move |ctx| {
        let per = LayerQuantConfig::uniform(&cfg, MaxStrategy::Tournament);
        bert_graph(ctx, &cfg, &per, if ctx.id == P0 { Some(&w) } else { None }).fingerprint()
    });
    assert_eq!(fps[0], fp(MaxStrategy::Tournament));
    assert_eq!(fps[0], fps[1]);
    assert_eq!(fps[1], fps[2]);
}

/// Per-layer knobs are a real per-layer API: mixing strategies across
/// layers builds, plans, and serves a consistent warm window.
#[test]
fn mixed_per_layer_strategies_stay_plan_consistent() {
    let cfg = BertConfig::tiny();
    let (w, _) = prepared_model(cfg);
    let inputs = prepared_inputs(&cfg, 2);
    let (_, snap) = run_3pc(SessionCfg::default(), move |ctx| {
        let mut per = LayerQuantConfig::uniform(&cfg, MaxStrategy::Tournament);
        per[1].max_strategy = MaxStrategy::Sort;
        per[1].sm_sx = 0.25; // per-layer softmax scale
        let g = bert_graph(ctx, &cfg, &per, if ctx.id == P0 { Some(&w) } else { None });
        let tape = g.prep(ctx, 2);
        ctx.install_corr(tape);
        secure_infer_batch(ctx, &g, 2, if ctx.id == P1 { Some(&inputs) } else { None });
        assert_eq!(ctx.corr_pending(), 0);
    });
    assert_eq!(snap.pool_misses(), 0, "mixed per-layer plan must cover the pass");
    assert!(snap.pool_hits() > 0);
}

/// The output-minimized classify head is also graph-derived: its tape
/// (including the argmax tournament's correlations) is consumed exactly
/// and warm/cold classes agree.
#[test]
fn classify_graph_is_plan_consistent() {
    let cfg = BertConfig::tiny();
    let run = |warm: bool| -> (u64, MetricsSnapshot) {
        let (w, x) = prepared_model(cfg);
        let (outs, snap) = run_3pc(SessionCfg::default(), move |ctx| {
            let per = LayerQuantConfig::uniform(&cfg, MaxStrategy::Tournament);
            let weights = if ctx.id == P0 { Some(&w) } else { None };
            let g = bert_classify_graph(ctx, &cfg, &per, weights);
            if warm {
                let tape = g.prep(ctx, 1);
                ctx.install_corr(tape);
            }
            let class = secure_classify(ctx, &g, if ctx.id == P1 { Some(&x) } else { None });
            assert_eq!(ctx.corr_pending(), 0);
            class
        });
        (outs[1], snap)
    };
    let (cold_class, _) = run(false);
    let (warm_class, warm_snap) = run(true);
    assert_eq!(warm_snap.pool_misses(), 0, "classify tape must cover argmax too");
    assert!(warm_snap.pool_hits() > 0);
    assert_eq!(warm_class, cold_class);
    assert!(warm_class < cfg.n_classes as u64);
}

/// Batch scaling is derived from shapes: the plan for B = 4 has the same
/// op sequence as B = 1 with 4× the element counts (groups included).
#[test]
fn plan_scales_linearly_with_batch() {
    let cfg = BertConfig::tiny();
    let g = bert_graph_dry(&cfg, &LayerQuantConfig::uniform(&cfg, MaxStrategy::Tournament));
    let p1 = g.plan_entries(1);
    let p4 = g.plan_entries(4);
    assert_eq!(p1.len(), p4.len(), "same op sequence regardless of batch");
    for (a, b) in p1.iter().zip(&p4) {
        assert_eq!(a.node, b.node);
        assert_eq!(a.shape.kind, b.shape.kind);
        assert_eq!(b.shape.n, 4 * a.shape.n, "{}: n must scale by the batch", a.node);
    }
}
