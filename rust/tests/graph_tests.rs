//! Secure op-graph consistency: the SAME graph object derives the
//! offline plan and runs the online pass, so for every builder × batch
//! size × `Π_max` realization the graph-derived tape must be consumed
//! exactly — no leftovers, no inline fallbacks — and a warm (prepped)
//! window's logits must be bit-identical to a cold one's
//! (DESIGN.md §Secure op graph). All graph construction goes through
//! the typed [`GraphSpec`] / [`MlpSpec`] entry points, including the
//! three non-classify task heads (ner / pair / embed).

use ppq_bert::bench_harness::{prepared_inputs, prepared_model};
use ppq_bert::model::config::{BertConfig, LayerQuantConfig, TaskKind};
use ppq_bert::model::passes::OptConfig;
use ppq_bert::model::secure::{
    secure_classify, secure_infer_batch, GraphSpec, MlpConfig, MlpSpec, MlpWeights,
};
use ppq_bert::party::{run_3pc, SessionCfg, P0, P1};
use ppq_bert::protocols::max::MaxStrategy;
use ppq_bert::transport::{MetricsSnapshot, Phase};

const STRATS: [MaxStrategy; 3] = [MaxStrategy::Tournament, MaxStrategy::Linear, MaxStrategy::Sort];
const OPTS: [OptConfig; 2] = [OptConfig::none(), OptConfig::o1()];

/// One classify window on a fresh session: build the graph, optionally
/// prep its tape through the graph walk, evaluate, and return (P1
/// logits, meter, plan length).
fn run_bert(
    strat: MaxStrategy,
    batch: usize,
    warm: bool,
) -> (Vec<Vec<i64>>, MetricsSnapshot, usize) {
    run_task_opt(TaskKind::Classify, strat, batch, warm, OptConfig::none(), 1)
}

/// [`run_bert`] with an explicit optimizer pipeline and worker-pool size.
fn run_bert_opt(
    strat: MaxStrategy,
    batch: usize,
    warm: bool,
    opt: OptConfig,
    threads: usize,
) -> (Vec<Vec<i64>>, MetricsSnapshot, usize) {
    run_task_opt(TaskKind::Classify, strat, batch, warm, opt, threads)
}

/// One BERT-trunk window for ANY task head on a fresh session — the
/// shared harness behind [`run_bert_opt`] and the new-head coverage.
fn run_task_opt(
    task: TaskKind,
    strat: MaxStrategy,
    batch: usize,
    warm: bool,
    opt: OptConfig,
    threads: usize,
) -> (Vec<Vec<i64>>, MetricsSnapshot, usize) {
    let cfg = BertConfig::tiny();
    let (w, _) = prepared_model(cfg);
    let inputs = prepared_inputs(&cfg, batch);
    let scfg = SessionCfg { threads, ..SessionCfg::default() };
    let (outs, snap) = run_3pc(scfg, move |ctx| {
        let weights = if ctx.id == P0 { Some(&w) } else { None };
        let g = GraphSpec::new(task, cfg).with_strategy(strat).with_opt(opt).build(ctx, weights);
        let plan_len = g.plan(batch).len();
        if warm {
            let tape = g.prep(ctx, batch);
            assert_eq!(tape.len(), plan_len);
            ctx.install_corr(tape);
        }
        let (rows, _) =
            secure_infer_batch(ctx, &g, batch, if ctx.id == P1 { Some(&inputs) } else { None });
        assert_eq!(ctx.corr_pending(), 0, "tape not fully consumed (plan drift)");
        (rows, plan_len)
    });
    let (rows, plan_len) = outs[1].clone();
    (rows, snap, plan_len)
}

/// One MLP window (the non-BERT builder) on a fresh session.
fn run_mlp(batch: usize, warm: bool) -> (Vec<Vec<i64>>, MetricsSnapshot, usize) {
    run_mlp_opt(batch, warm, OptConfig::none(), 1)
}

/// [`run_mlp`] with an explicit optimizer pipeline and worker-pool size.
fn run_mlp_opt(
    batch: usize,
    warm: bool,
    opt: OptConfig,
    threads: usize,
) -> (Vec<Vec<i64>>, MetricsSnapshot, usize) {
    let mcfg = MlpConfig::tiny();
    let inputs: Vec<Vec<i64>> = (0..batch)
        .map(|b| (0..mcfg.d_in).map(|i| ((i + 3 * b) % 15) as i64 - 7).collect())
        .collect();
    let scfg = SessionCfg { threads, ..SessionCfg::default() };
    let (outs, snap) = run_3pc(scfg, move |ctx| {
        let mw = if ctx.id == P0 { Some(MlpWeights::synth(&mcfg, 7)) } else { None };
        let g = MlpSpec::new(mcfg).with_opt(opt).build(ctx, mw.as_ref());
        let plan_len = g.plan(batch).len();
        if warm {
            let tape = g.prep(ctx, batch);
            assert_eq!(tape.len(), plan_len);
            ctx.install_corr(tape);
        }
        let (logits, _) =
            secure_infer_batch(ctx, &g, batch, if ctx.id == P1 { Some(&inputs) } else { None });
        assert_eq!(ctx.corr_pending(), 0, "tape not fully consumed (plan drift)");
        (logits, plan_len)
    });
    let (logits, plan_len) = outs[1].clone();
    (logits, snap, plan_len)
}

/// The headline property: every builder × batch ∈ {1, 4} × every
/// `Π_max` strategy consumes its graph-derived tape exactly (warm run:
/// hits == plan length, zero misses; cold run: misses == plan length)
/// and warm-vs-cold logits are bit-identical.
#[test]
fn plan_consistency_every_builder_batch_strategy() {
    for strat in STRATS {
        for batch in [1usize, 4] {
            let (cold_logits, cold, plan_len) = run_bert(strat, batch, false);
            let (warm_logits, warm, _) = run_bert(strat, batch, true);
            assert!(plan_len > 0);
            assert_eq!(cold.pool_misses(), plan_len as u64, "{strat:?} B={batch}: cold misses");
            assert_eq!(cold.pool_hits(), 0, "{strat:?} B={batch}");
            assert_eq!(warm.pool_hits(), plan_len as u64, "{strat:?} B={batch}: warm hits");
            assert_eq!(warm.pool_misses(), 0, "{strat:?} B={batch}: warm misses");
            assert_eq!(warm_logits, cold_logits, "{strat:?} B={batch}: warm/cold logits");
        }
    }
    for batch in [1usize, 4] {
        let (cold_logits, cold, plan_len) = run_mlp(batch, false);
        let (warm_logits, warm, _) = run_mlp(batch, true);
        assert!(plan_len > 0);
        assert_eq!(cold.pool_misses(), plan_len as u64, "mlp B={batch}: cold misses");
        assert_eq!(warm.pool_hits(), plan_len as u64, "mlp B={batch}: warm hits");
        assert_eq!(warm.pool_misses(), 0, "mlp B={batch}");
        assert_eq!(warm_logits, cold_logits, "mlp B={batch}: warm/cold logits");
    }
}

/// The dry (share-less) builder models offline cost exactly: a cold
/// window's metered `Phase::Offline` bytes equal the dry graph's
/// per-op byte accounting, summed.
#[test]
fn dry_plan_bytes_match_metered_offline_traffic() {
    for batch in [1usize, 2] {
        let (_, cold, _) = run_bert(MaxStrategy::Tournament, batch, false);
        let cfg = BertConfig::tiny();
        let g = GraphSpec::new(TaskKind::Classify, cfg).dry();
        let modeled: u64 = g.plan_entries(batch).iter().map(|e| e.bytes).sum();
        assert_eq!(
            cold.total_bytes(Phase::Offline),
            modeled,
            "B={batch}: modeled per-op bytes must equal the metered offline traffic"
        );
    }
}

/// Fingerprints key the serving tape pools: equal for structurally
/// identical graphs (live and dry builds included), different across
/// strategies and across builders.
#[test]
fn fingerprints_track_graph_structure() {
    let cfg = BertConfig::tiny();
    let fp =
        |strat: MaxStrategy| GraphSpec::new(TaskKind::Classify, cfg).with_strategy(strat).dry().fingerprint();
    assert_eq!(fp(MaxStrategy::Tournament), fp(MaxStrategy::Tournament));
    assert_ne!(fp(MaxStrategy::Tournament), fp(MaxStrategy::Sort));
    assert_ne!(fp(MaxStrategy::Tournament), fp(MaxStrategy::Linear));
    assert_ne!(fp(MaxStrategy::Tournament), MlpSpec::new(MlpConfig::tiny()).dry().fingerprint());

    // The live build (with real shares) has the same structure, hence
    // the same fingerprint, as the dry build.
    let (w, _) = prepared_model(cfg);
    let (fps, _) = run_3pc(SessionCfg::default(), move |ctx| {
        GraphSpec::new(TaskKind::Classify, cfg)
            .build(ctx, if ctx.id == P0 { Some(&w) } else { None })
            .fingerprint()
    });
    assert_eq!(fps[0], fp(MaxStrategy::Tournament));
    assert_eq!(fps[0], fps[1]);
    assert_eq!(fps[1], fps[2]);
}

/// Per-layer knobs are a real per-layer API: mixing strategies across
/// layers builds, plans, and serves a consistent warm window.
#[test]
fn mixed_per_layer_strategies_stay_plan_consistent() {
    let cfg = BertConfig::tiny();
    let (w, _) = prepared_model(cfg);
    let inputs = prepared_inputs(&cfg, 2);
    let (_, snap) = run_3pc(SessionCfg::default(), move |ctx| {
        let mut per = LayerQuantConfig::uniform(&cfg, MaxStrategy::Tournament);
        per[1].max_strategy = MaxStrategy::Sort;
        per[1].sm_sx = 0.25; // per-layer softmax scale
        let g = GraphSpec::new(TaskKind::Classify, cfg)
            .with_quant(per)
            .build(ctx, if ctx.id == P0 { Some(&w) } else { None });
        let tape = g.prep(ctx, 2);
        ctx.install_corr(tape);
        secure_infer_batch(ctx, &g, 2, if ctx.id == P1 { Some(&inputs) } else { None });
        assert_eq!(ctx.corr_pending(), 0);
    });
    assert_eq!(snap.pool_misses(), 0, "mixed per-layer plan must cover the pass");
    assert!(snap.pool_hits() > 0);
}

/// The output-minimized classify head is also graph-derived: its tape
/// (including the argmax tournament's correlations) is consumed exactly
/// and warm/cold classes agree.
#[test]
fn classify_graph_is_plan_consistent() {
    let cfg = BertConfig::tiny();
    let run = |warm: bool| -> (u64, MetricsSnapshot) {
        let (w, x) = prepared_model(cfg);
        let (outs, snap) = run_3pc(SessionCfg::default(), move |ctx| {
            let weights = if ctx.id == P0 { Some(&w) } else { None };
            let g = GraphSpec::new(TaskKind::Classify, cfg).build_argmax(ctx, weights);
            if warm {
                let tape = g.prep(ctx, 1);
                ctx.install_corr(tape);
            }
            let class = secure_classify(ctx, &g, if ctx.id == P1 { Some(&x) } else { None });
            assert_eq!(ctx.corr_pending(), 0);
            class
        });
        (outs[1], snap)
    };
    let (cold_class, _) = run(false);
    let (warm_class, warm_snap) = run(true);
    assert_eq!(warm_snap.pool_misses(), 0, "classify tape must cover argmax too");
    assert!(warm_snap.pool_hits() > 0);
    assert_eq!(warm_class, cold_class);
    assert!(warm_class < cfg.n_classes as u64);
}

/// Every builder × opt level keeps the graph invariants: the warm tape
/// is consumed exactly (hits == plan length, zero misses), warm and
/// cold logits agree, and the dry builder's modeled bytes equal the
/// metered offline traffic at BOTH opt levels (packing moves message
/// boundaries, never bytes — DESIGN.md §Graph optimizer).
#[test]
fn opt_levels_stay_plan_consistent_for_every_builder() {
    let batch = 2usize;
    for opt in OPTS {
        let (cold_logits, cold, plan_len) =
            run_bert_opt(MaxStrategy::Tournament, batch, false, opt, 1);
        let (warm_logits, warm, _) = run_bert_opt(MaxStrategy::Tournament, batch, true, opt, 1);
        assert!(plan_len > 0);
        assert_eq!(cold.pool_misses(), plan_len as u64, "bert {opt:?}: cold misses");
        assert_eq!(warm.pool_hits(), plan_len as u64, "bert {opt:?}: warm hits");
        assert_eq!(warm.pool_misses(), 0, "bert {opt:?}: warm misses");
        assert_eq!(warm_logits, cold_logits, "bert {opt:?}: warm/cold logits");
        let cfg = BertConfig::tiny();
        let g = GraphSpec::new(TaskKind::Classify, cfg).with_opt(opt).dry();
        let modeled: u64 = g.plan_entries(batch).iter().map(|e| e.bytes).sum();
        assert_eq!(cold.total_bytes(Phase::Offline), modeled, "bert {opt:?}: modeled bytes");

        let (mcold_logits, mcold, mplan_len) = run_mlp_opt(batch, false, opt, 1);
        let (mwarm_logits, mwarm, _) = run_mlp_opt(batch, true, opt, 1);
        assert!(mplan_len > 0);
        assert_eq!(mcold.pool_misses(), mplan_len as u64, "mlp {opt:?}: cold misses");
        assert_eq!(mwarm.pool_hits(), mplan_len as u64, "mlp {opt:?}: warm hits");
        assert_eq!(mwarm.pool_misses(), 0, "mlp {opt:?}: warm misses");
        assert_eq!(mwarm_logits, mcold_logits, "mlp {opt:?}: warm/cold logits");
        let mg = MlpSpec::new(MlpConfig::tiny()).with_opt(opt).dry();
        let mmodeled: u64 = mg.plan_entries(batch).iter().map(|e| e.bytes).sum();
        assert_eq!(mcold.total_bytes(Phase::Offline), mmodeled, "mlp {opt:?}: modeled bytes");
    }
}

/// The three non-classify task heads are first-class graph builders
/// (DESIGN.md §Heterogeneous serving): for every task × opt level, the
/// warm tape is consumed exactly, warm and cold outputs are
/// bit-identical, the dry builder's modeled bytes equal the metered
/// offline traffic, outputs have the task-appropriate width, and
/// `threads ∈ {1, 4}` changes nothing but wall-clock.
#[test]
fn new_task_heads_stay_plan_consistent() {
    let batch = 2usize;
    let cfg = BertConfig::tiny();
    for task in [TaskKind::Ner, TaskKind::Pair, TaskKind::Embed] {
        for opt in OPTS {
            let tag = format!("{} {opt:?}", task.as_str());
            let (cold_rows, cold, plan_len) =
                run_task_opt(task, MaxStrategy::Tournament, batch, false, opt, 1);
            let (warm_rows, warm, _) =
                run_task_opt(task, MaxStrategy::Tournament, batch, true, opt, 1);
            assert!(plan_len > 0, "{tag}");
            assert_eq!(cold.pool_misses(), plan_len as u64, "{tag}: cold misses");
            assert_eq!(cold.pool_hits(), 0, "{tag}: cold hits");
            assert_eq!(warm.pool_hits(), plan_len as u64, "{tag}: warm hits");
            assert_eq!(warm.pool_misses(), 0, "{tag}: warm misses");
            assert_eq!(warm_rows, cold_rows, "{tag}: warm/cold outputs");

            // Revealed rows regroup to one task-shaped output per request.
            let spec = GraphSpec::new(task, cfg).with_opt(opt);
            assert_eq!(warm_rows.len() % batch, 0, "{tag}: rows must cover the window");
            let per_request: usize = warm_rows[..warm_rows.len() / batch]
                .iter()
                .map(|r| r.len())
                .sum();
            assert_eq!(per_request, spec.out_len(), "{tag}: per-request output width");

            let dry = spec.dry();
            let modeled: u64 = dry.plan_entries(batch).iter().map(|e| e.bytes).sum();
            assert_eq!(cold.total_bytes(Phase::Offline), modeled, "{tag}: modeled bytes");

            // The parallel-runtime invariant holds for the new heads too.
            let (t4_rows, t4, _) =
                run_task_opt(task, MaxStrategy::Tournament, batch, true, opt, 4);
            assert_eq!(t4_rows, warm_rows, "{tag}: T=4 outputs");
            assert_meters_eq(&t4, &warm, &format!("{tag} T=4"));
        }
    }

    // Task-tagged graphs never share a tape pool with the classify
    // trunk or with each other: all four fingerprints are distinct.
    let mut fps: Vec<u64> = [TaskKind::Classify, TaskKind::Ner, TaskKind::Pair, TaskKind::Embed]
        .iter()
        .map(|&t| GraphSpec::new(t, cfg).dry().fingerprint())
        .collect();
    fps.sort_unstable();
    fps.dedup();
    assert_eq!(fps.len(), 4, "task heads must have distinct fingerprints");
}

/// The classify builder is opt-aware too: warm windows at every level
/// consume their tape exactly and agree on the argmax class, and its
/// fingerprint re-keys per opt level.
#[test]
fn classify_graph_stays_plan_consistent_across_opt_levels() {
    let cfg = BertConfig::tiny();
    let run = |warm: bool, opt: OptConfig| -> (u64, u64, MetricsSnapshot) {
        let (w, x) = prepared_model(cfg);
        let (outs, snap) = run_3pc(SessionCfg::default(), move |ctx| {
            let weights = if ctx.id == P0 { Some(&w) } else { None };
            let g = GraphSpec::new(TaskKind::Classify, cfg).with_opt(opt).build_argmax(ctx, weights);
            if warm {
                let tape = g.prep(ctx, 1);
                ctx.install_corr(tape);
            }
            let class = secure_classify(ctx, &g, if ctx.id == P1 { Some(&x) } else { None });
            assert_eq!(ctx.corr_pending(), 0);
            (class, g.fingerprint())
        });
        (outs[1].0, outs[1].1, snap)
    };
    let mut fps = Vec::new();
    let mut classes = Vec::new();
    for opt in OPTS {
        let (cold_class, fp, _) = run(false, opt);
        let (warm_class, _, warm_snap) = run(true, opt);
        assert_eq!(warm_snap.pool_misses(), 0, "classify {opt:?}: warm misses");
        assert!(warm_snap.pool_hits() > 0, "classify {opt:?}");
        assert_eq!(warm_class, cold_class, "classify {opt:?}: warm/cold class");
        fps.push(fp);
        classes.push(cold_class);
    }
    assert_ne!(fps[0], fps[1], "classify fingerprint must re-key per opt level");
    assert_eq!(classes[0], classes[1], "opt level must not change the class");
}

/// Fingerprints re-key across opt levels for every builder, so tapes
/// persisted at one level are never served at another.
#[test]
fn fingerprints_rekey_across_opt_levels_for_every_builder() {
    let cfg = BertConfig::tiny();
    let bert_fp =
        |opt: OptConfig| GraphSpec::new(TaskKind::Classify, cfg).with_opt(opt).dry().fingerprint();
    assert_ne!(bert_fp(OptConfig::none()), bert_fp(OptConfig::o1()));
    let mlp_fp = |opt: OptConfig| MlpSpec::new(MlpConfig::tiny()).with_opt(opt).dry().fingerprint();
    assert_ne!(mlp_fp(OptConfig::none()), mlp_fp(OptConfig::o1()));
}

/// Deterministic meter fields must match exactly; `compute_ns` is the
/// only field thread count may change.
fn assert_meters_eq(got: &MetricsSnapshot, want: &MetricsSnapshot, what: &str) {
    assert_eq!(got.bytes, want.bytes, "{what}: bytes");
    assert_eq!(got.msgs, want.msgs, "{what}: msgs");
    assert_eq!(got.rounds, want.rounds, "{what}: rounds");
    assert_eq!(got.prep_hits, want.prep_hits, "{what}: prep hits");
    assert_eq!(got.prep_misses, want.prep_misses, "{what}: prep misses");
}

/// Tentpole invariant of the parallel runtime
/// (DESIGN.md §Parallel runtime): the worker-pool size changes
/// wall-clock ONLY. For both
/// builders × both opt levels × warm and cold tapes, the logits and
/// every deterministic meter field (per-link/phase bytes, messages,
/// rounds, prep hits/misses) are bit-identical across
/// `threads ∈ {1, 2, 4, 8}`.
#[test]
fn thread_count_never_changes_outputs_or_meters() {
    let batch = 1usize;
    for opt in OPTS {
        for warm in [false, true] {
            let (want_logits, want, _) =
                run_bert_opt(MaxStrategy::Tournament, batch, warm, opt, 1);
            let (mwant_logits, mwant, _) = run_mlp_opt(batch, warm, opt, 1);
            for threads in [2usize, 4, 8] {
                let tag = format!("bert {opt:?} warm={warm} T={threads}");
                let (logits, snap, _) =
                    run_bert_opt(MaxStrategy::Tournament, batch, warm, opt, threads);
                assert_eq!(logits, want_logits, "{tag}: logits");
                assert_meters_eq(&snap, &want, &tag);
                let mtag = format!("mlp {opt:?} warm={warm} T={threads}");
                let (mlogits, msnap, _) = run_mlp_opt(batch, warm, opt, threads);
                assert_eq!(mlogits, mwant_logits, "{mtag}: logits");
                assert_meters_eq(&msnap, &mwant, &mtag);
            }
        }
    }
}

/// Batch scaling is derived from shapes: the plan for B = 4 has the same
/// op sequence as B = 1 with 4× the element counts (groups included).
#[test]
fn plan_scales_linearly_with_batch() {
    let cfg = BertConfig::tiny();
    let g = GraphSpec::new(TaskKind::Classify, cfg).dry();
    let p1 = g.plan_entries(1);
    let p4 = g.plan_entries(4);
    assert_eq!(p1.len(), p4.len(), "same op sequence regardless of batch");
    for (a, b) in p1.iter().zip(&p4) {
        assert_eq!(a.node, b.node);
        assert_eq!(a.shape.kind, b.shape.kind);
        assert_eq!(b.shape.n, 4 * a.shape.n, "{}: n must scale by the batch", a.node);
    }
}
