//! Cross-layer integration: the AOT HLO artifact (L1 Pallas + L2 JAX,
//! lowered by python) must agree with the Rust native oracle bit-exactly,
//! closing the chain of trust:
//!   pallas == jnp ref (pytest) == HLO artifact == rust native == MPC.
//!
//! These tests skip (pass trivially with a notice) when `make artifacts`
//! has not been run.

use std::path::PathBuf;

use ppq_bert::model::config::BertConfig;
use ppq_bert::model::weights::{read_i32_file, Weights};
use ppq_bert::runtime::native;
use ppq_bert::runtime::xla::{artifacts_dir, I32Tensor, XlaModel};

fn artifact(name: &str) -> Option<PathBuf> {
    let p = artifacts_dir().join(name);
    if p.exists() {
        Some(p)
    } else {
        eprintln!("skipping: {} missing (run `make artifacts`)", p.display());
        None
    }
}

#[test]
fn bert_tiny_artifact_matches_native_oracle() {
    let (Some(hlo), Some(wpath), Some(inpath)) = (
        artifact("bert_tiny.hlo.txt"),
        artifact("bert_tiny.weights.bin"),
        artifact("bert_tiny.input.bin"),
    ) else {
        return;
    };
    let w = Weights::load(&wpath).unwrap();
    let cfg = w.cfg;
    let (xshape, xdata) = read_i32_file(&inpath).unwrap();
    assert_eq!(xshape, vec![cfg.seq_len, cfg.d_model]);

    // Native oracle forward.
    let (logits_native, h_native) = native::forward(&cfg, &w, &xdata);

    // XLA artifact forward: inputs are (x4, *weights in param order).
    let model = XlaModel::load(&hlo).unwrap();
    let mut inputs = vec![I32Tensor::from_i64(xshape, &xdata)];
    for li in 0..cfg.n_layers {
        for p in BertConfig::layer_params() {
            let t = w.tensor(&format!("layer{li}.{p}"));
            inputs.push(I32Tensor::from_i64(t.shape.clone(), &t.data));
        }
    }
    let t = w.tensor("cls.w");
    inputs.push(I32Tensor::from_i64(t.shape.clone(), &t.data));

    let outs = model.run(&inputs).unwrap();
    assert_eq!(outs.len(), 2, "expected (logits, hidden)");
    let logits_xla: Vec<i64> = outs[0].data.iter().map(|&v| v as i64).collect();
    let h_xla: Vec<i64> = outs[1].data.iter().map(|&v| v as i64).collect();

    assert_eq!(logits_xla, logits_native, "logits: artifact != native");
    assert_eq!(h_xla, h_native, "hidden: artifact != native");
}

#[test]
fn bert_tiny_artifact_matches_python_expectation() {
    // The .expect.bin sidecar pins the python-side output; the artifact
    // must reproduce it (python wrote both, so this guards artifact/weights
    // mismatch after partial rebuilds).
    let (Some(hlo), Some(wpath), Some(inpath), Some(expath), Some(hidpath)) = (
        artifact("bert_tiny.hlo.txt"),
        artifact("bert_tiny.weights.bin"),
        artifact("bert_tiny.input.bin"),
        artifact("bert_tiny.expect.bin"),
        artifact("bert_tiny.hidden.bin"),
    ) else {
        return;
    };
    let w = Weights::load(&wpath).unwrap();
    let cfg = w.cfg;
    let (xshape, xdata) = read_i32_file(&inpath).unwrap();
    let (_, expect_logits) = read_i32_file(&expath).unwrap();
    let (_, expect_hidden) = read_i32_file(&hidpath).unwrap();

    let model = XlaModel::load(&hlo).unwrap();
    let mut inputs = vec![I32Tensor::from_i64(xshape, &xdata)];
    for li in 0..cfg.n_layers {
        for p in BertConfig::layer_params() {
            let t = w.tensor(&format!("layer{li}.{p}"));
            inputs.push(I32Tensor::from_i64(t.shape.clone(), &t.data));
        }
    }
    let t = w.tensor("cls.w");
    inputs.push(I32Tensor::from_i64(t.shape.clone(), &t.data));
    let outs = model.run(&inputs).unwrap();

    let logits: Vec<i64> = outs[0].data.iter().map(|&v| v as i64).collect();
    let hidden: Vec<i64> = outs[1].data.iter().map(|&v| v as i64).collect();
    assert_eq!(logits, expect_logits);
    assert_eq!(hidden, expect_hidden);
}

#[test]
fn fc_kernel_artifact_matches_native() {
    let Some(hlo) = artifact("fc_quant.hlo.txt") else {
        return;
    };
    // Shapes/scale pinned by aot.py: x[8,64], w[64,64], scale 64.
    let (seq, d, scale) = (8usize, 64usize, 64i64);
    let model = XlaModel::load(&hlo).unwrap();
    let x: Vec<i64> = (0..seq * d).map(|i| ((i * 7) % 16) as i64 - 8).collect();
    let wdata: Vec<i64> = (0..d * d).map(|i| if (i * 13) % 2 == 0 { 1 } else { -1 }).collect();
    let outs = model
        .run(&[
            I32Tensor::from_i64(vec![seq, d], &x),
            I32Tensor::from_i64(vec![d, d], &wdata),
        ])
        .unwrap();
    let got: Vec<i64> = outs[0].data.iter().map(|&v| v as i64).collect();
    let wt = ppq_bert::model::weights::Tensor { shape: vec![d, d], data: wdata };
    let want = native::fc_quant(&x, seq, d, &wt, scale);
    assert_eq!(got, want, "Pallas FC artifact != native fc_quant");
}

#[test]
fn softmax_kernel_artifact_matches_native() {
    let Some(hlo) = artifact("softmax_quant.hlo.txt") else {
        return;
    };
    // Pinned by aot.py: x[8,8], sx = TINY.sm_sx = 0.5.
    let (rows, n, sx) = (8usize, 8usize, 0.5f64);
    let model = XlaModel::load(&hlo).unwrap();
    let x: Vec<i64> = (0..rows * n).map(|i| ((i * 5) % 16) as i64 - 8).collect();
    let outs = model.run(&[I32Tensor::from_i64(vec![rows, n], &x)]).unwrap();
    let got: Vec<i64> = outs[0].data.iter().map(|&v| v as i64).collect();
    let want = native::softmax_quant(&x, rows, n, sx);
    assert_eq!(got, want, "Pallas softmax artifact != native softmax_quant");
}
