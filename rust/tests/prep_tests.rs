//! Offline/online split integration: a warm correlation pool must move
//! ALL offline-phase communication off the request path without changing
//! anything the request path computes.
//!
//! The three pinned properties (DESIGN.md §Offline preprocessing):
//!   1. a warm-pool `secure_infer_batch` window records ZERO
//!      `Phase::Offline` bytes and rounds;
//!   2. its modeled request-path latency is strictly below the cold-pool
//!      window's (same online traffic, no offline component);
//!   3. warm and cold logits agree BIT-FOR-BIT — preprocessing draws
//!      from dedicated PRG streams, so generating material ahead of time
//!      consumes exactly the randomness inline generation would.

use ppq_bert::bench_harness::{prepared_inputs, prepared_model};
use ppq_bert::coordinator::{Coordinator, ServerConfig, Session};
use ppq_bert::model::config::{BertConfig, TaskKind};
use ppq_bert::model::secure::{secure_infer_batch, GraphSpec};
use ppq_bert::model::weights::Weights;
use ppq_bert::party::{run_3pc, SessionCfg, P0, P1};
use ppq_bert::protocols::max::MaxStrategy;
use ppq_bert::transport::{MetricsSnapshot, NetParams, Phase};

fn clone_weights(w: &Weights, cfg: BertConfig) -> Weights {
    Weights {
        cfg,
        tensors: w.tensors.clone(),
        scales: w.scales.clone(),
    }
}

/// Serve one window of `batch` requests on a fresh session, optionally
/// prepping its correlation tape first. Returns the logits and the
/// request-path (infer-only) meter delta.
fn serve_window(
    cfg: BertConfig,
    w: Weights,
    inputs: &[Vec<i64>],
    warm: bool,
) -> (Vec<Vec<i64>>, MetricsSnapshot) {
    let sess = Session::start(cfg, w, SessionCfg::default(), MaxStrategy::Tournament);
    if warm {
        sess.prep(inputs.len());
    }
    let pre = sess.snapshot();
    let logits = sess.infer_batch(inputs);
    let mut delta = sess.snapshot();
    delta.saturating_sub_assign(&pre);
    sess.shutdown();
    (logits, delta)
}

/// The headline invariant at B = 1 and B = 4: warm windows perform zero
/// offline-phase communication, pay strictly less modeled request-path
/// latency than cold windows, and produce bit-identical logits.
#[test]
fn warm_pool_has_zero_offline_traffic_and_identical_logits() {
    let cfg = BertConfig::tiny();
    for batch in [1usize, 4] {
        let (w, _) = prepared_model(cfg);
        let inputs = prepared_inputs(&cfg, batch);

        let (cold_logits, cold) = serve_window(cfg, clone_weights(&w, cfg), &inputs, false);
        let (warm_logits, warm) = serve_window(cfg, w, &inputs, true);

        // 1. zero offline-phase communication on the warm request path
        assert!(cold.total_bytes(Phase::Offline) > 0, "B={batch}: cold window is offline-heavy");
        assert!(cold.max_rounds(Phase::Offline) > 0);
        assert_eq!(warm.total_bytes(Phase::Offline), 0, "B={batch}: warm offline bytes");
        assert_eq!(warm.max_rounds(Phase::Offline), 0, "B={batch}: warm offline rounds");
        // every LUT invocation was served from the pool
        assert_eq!(warm.pool_misses(), 0, "B={batch}");
        assert!(warm.pool_hits() > 0, "B={batch}");
        assert_eq!(cold.pool_hits(), 0, "B={batch}");

        // online traffic is untouched by pooling
        assert_eq!(
            warm.total_bytes(Phase::Online),
            cold.total_bytes(Phase::Online),
            "B={batch}: pooling must not change online bytes"
        );
        assert_eq!(warm.max_rounds(Phase::Online), cold.max_rounds(Phase::Online));

        // 2. strictly less modeled request-path time (deterministic
        //    network model over the measured counters; compute excluded)
        for net in [NetParams::LAN, NetParams::WAN] {
            let path = |d: &MetricsSnapshot| {
                net.modeled_net_time(d, Phase::Offline) + net.modeled_net_time(d, Phase::Online)
            };
            assert!(
                path(&warm) < path(&cold),
                "B={batch} {}: warm {:?} !< cold {:?}",
                net.name,
                path(&warm),
                path(&cold)
            );
        }

        // 3. bit-for-bit logits parity
        assert_eq!(warm_logits, cold_logits, "B={batch}: warm/cold logits must be identical");
    }
}

/// The graph-derived tape aligns with the online walk exactly: the tape
/// is consumed item for item (every acquire is a hit, nothing left
/// over). The exhaustive builder × batch × strategy sweep lives in
/// `rust/tests/graph_tests.rs`; this pins the session-facing shape.
#[test]
fn prep_tape_aligns_with_online_consumption() {
    let cfg = BertConfig::tiny();
    for batch in [1usize, 2, 3] {
        let (w, _) = prepared_model(cfg);
        let inputs = prepared_inputs(&cfg, batch);
        let (wc, inc) = (w, inputs);
        let (plan_lens, snap) = {
            let (outs, snap) = run_3pc(SessionCfg::default(), move |ctx| {
                let m = GraphSpec::new(TaskKind::Classify, cfg)
                    .build(ctx, if ctx.id == P0 { Some(&wc) } else { None });
                let plan_len = m.plan(batch).len();
                let tape = m.prep(ctx, batch);
                assert_eq!(tape.len(), plan_len);
                ctx.install_corr(tape);
                secure_infer_batch(ctx, &m, batch, if ctx.id == P1 { Some(&inc) } else { None });
                assert_eq!(ctx.corr_pending(), 0, "tape fully consumed");
                plan_len
            });
            (outs, snap)
        };
        let plan_len = plan_lens[0] as u64;
        assert!(plan_len > 0);
        assert_eq!(snap.pool_hits(), plan_len, "B={batch}: every plan op consumed as a hit");
        assert_eq!(snap.pool_misses(), 0, "B={batch}");
    }
}

/// The graph walk covers every MaxStrategy (the softmax max-reduction
/// is the only strategy-dependent LUT sequence).
#[test]
fn prep_covers_every_max_strategy() {
    let cfg = BertConfig::tiny();
    for strat in [MaxStrategy::Tournament, MaxStrategy::Linear, MaxStrategy::Sort] {
        let (w, _) = prepared_model(cfg);
        let inputs = prepared_inputs(&cfg, 2);
        let (wc, inc) = (w, inputs);
        let (_, snap) = run_3pc(SessionCfg::default(), move |ctx| {
            let m = GraphSpec::new(TaskKind::Classify, cfg)
                .with_strategy(strat)
                .build(ctx, if ctx.id == P0 { Some(&wc) } else { None });
            let tape = m.prep(ctx, 2);
            ctx.install_corr(tape);
            secure_infer_batch(ctx, &m, 2, if ctx.id == P1 { Some(&inc) } else { None });
            assert_eq!(ctx.corr_pending(), 0);
        });
        assert_eq!(snap.pool_misses(), 0, "{strat:?}: plan must cover the whole pass");
    }
}

/// Coordinator-level lifecycle: a prefilled pool serves full windows
/// warm (zero request-path offline bytes in the per-request accounting),
/// the pool refills between windows, and the report exposes the hit/miss
/// counters.
#[test]
fn coordinator_pool_serves_windows_warm() {
    let cfg = BertConfig::tiny();
    let (w, _) = prepared_model(cfg);
    let mut sc = ServerConfig::new(cfg);
    sc.max_batch = 2;
    sc.prep_depth = 1;
    let mut coord = Coordinator::start(sc, w);
    assert_eq!(coord.pooled(2), 1, "start() prefills the pool");

    for x in prepared_inputs(&cfg, 4) {
        coord.submit(x);
    }
    // two full windows, both warm (run_batch refills between windows)
    for window in 0..2 {
        let results = coord.run_batch();
        assert_eq!(results.len(), 2);
        for r in &results {
            assert_eq!(r.window_pool_misses, 0, "window {window} must be warm");
            assert!(r.window_pool_hits > 0);
            assert_eq!(r.offline_bytes, 0, "warm window request-path offline bytes");
            assert!(r.online_bytes > 0);
            assert_eq!(r.offline_modeled, std::time::Duration::ZERO);
        }
    }
    assert_eq!(coord.pooled(2), 1, "pool topped back up after draining");
    assert!(coord.prepped_windows() >= 3);
    let report = coord.metrics_report();
    assert!(report.contains("pool_hits="), "{report}");
    assert!(report.contains("pool_misses=0"), "{report}");
    coord.shutdown();
}

/// A partial tail window (no tape of its size pooled) falls back to
/// inline generation: correct results, misses counted, full-size pool
/// left intact.
#[test]
fn partial_window_falls_back_inline() {
    let cfg = BertConfig::tiny();
    let (w, _) = prepared_model(cfg);
    let mut sc = ServerConfig::new(cfg);
    sc.max_batch = 4;
    sc.prep_depth = 1;
    let mut coord = Coordinator::start(sc, w);
    for x in prepared_inputs(&cfg, 3) {
        coord.submit(x); // window of 3 != prepped size 4
    }
    let results = coord.run_batch();
    assert_eq!(results.len(), 3);
    for r in &results {
        assert!(r.window_pool_misses > 0, "cold tail window counts misses");
        assert!(r.offline_bytes > 0, "inline generation lands on the request path");
        assert_eq!(r.logits.len(), cfg.n_classes);
    }
    assert_eq!(coord.pooled(4), 1, "the full-size tape is untouched");
    coord.shutdown();
}
