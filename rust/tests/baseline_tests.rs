//! Baseline comparator tests: the relative cost relationships the paper's
//! tables depend on must hold in our implementations.

use ppq_bert::baselines::{crypten, lu_ndss, sigma};
use ppq_bert::bench_harness::prepared_model;
use ppq_bert::model::config::BertConfig;
use ppq_bert::party::{run_3pc, SessionCfg, P1};
use ppq_bert::transport::Phase;

#[test]
fn crypten_comm_dwarfs_ours_per_layer_shape() {
    // One tiny-config inference in each system; CrypTen-style 64-bit
    // fixed-point must spend far more online bytes than the 4-bit design.
    let cfg = BertConfig::tiny();
    let (w, x) = prepared_model(cfg);

    let ours_online = {
        let (wc, xc) = (clone_w(&w, cfg), x.clone());
        use ppq_bert::model::config::TaskKind;
        use ppq_bert::model::secure::{secure_infer, GraphSpec};
        let (_, snap) = run_3pc(SessionCfg::default(), move |ctx| {
            let m = GraphSpec::new(TaskKind::Classify, cfg)
                .build(ctx, if ctx.id == 0 { Some(&wc) } else { None });
            secure_infer(ctx, &m, if ctx.id == P1 { Some(&xc) } else { None });
        });
        snap.total_bytes(Phase::Online)
    };

    let crypten_online = {
        let wc = clone_w(&w, cfg);
        let xf: Vec<f64> = x.iter().map(|&v| v as f64 / 8.0).collect();
        let (_, snap) = run_3pc(SessionCfg::default(), move |ctx| {
            crypten::crypten_infer(ctx, &cfg, &wc, if ctx.id == P1 { Some(&xf) } else { None });
        });
        snap.total_bytes(Phase::Online)
    };
    assert!(
        crypten_online > ours_online * 5,
        "crypten {crypten_online} vs ours {ours_online}"
    );
}

#[test]
fn lu_ndss_offline_gap_matches_paper_direction() {
    // Table 3's shape: the LUT-multiplication design pays an order of
    // magnitude more offline communication on FC layers.
    let ((lu_off, lu_on), (our_off, our_on)) =
        lu_ndss::compare_fc_comm(&BertConfig::tiny(), 8, 64, 16);
    assert!(lu_off > our_off * 10, "lu {lu_off} ours {our_off}");
    // online: both are small; lu pays two 4-bit openings per gate
    assert!(lu_on > our_on, "lu {lu_on} ours {our_on}");
}

#[test]
fn sigma_model_reproduces_published_points() {
    assert!((sigma::comm_mb(8) - 43.28).abs() < 1e-6);
    assert!((sigma::comm_mb(64) - 421.09).abs() < 1e-6);
    // paper's Table 2: Sigma 4-thread ~12.3s
    assert!((sigma::latency_ms(128, 4) - 12311.4).abs() < 1.0);
}

fn clone_w(
    w: &ppq_bert::model::weights::Weights,
    cfg: BertConfig,
) -> ppq_bert::model::weights::Weights {
    ppq_bert::model::weights::Weights {
        cfg,
        tensors: w.tensors.clone(),
        scales: w.scales.clone(),
    }
}
