//! Concurrent multi-client serving over TCP
//! (DESIGN.md §Concurrent serving): the wire-path admission queue +
//! cross-client dynamic batcher must fold simultaneous clients into
//! shared MPC windows
//! WITHOUT changing a single bit of the protocol — logits and the
//! per-link/per-phase meter must equal an in-process session evaluating
//! the same window compositions — while backpressure and client
//! disconnects stay strictly local to the affected request.

use std::collections::BTreeMap;
use std::net::TcpListener;
use std::sync::{mpsc, Arc, Barrier};
use std::thread::JoinHandle;
use std::time::Duration;

use ppq_bert::bench_harness::prepared_model;
use ppq_bert::coordinator::remote::{
    deployment_session_id, pad_to_bucket, run_party, served_keys, Completed, InferenceRequest,
    PartyOpts, RemoteClient, ServeOpts, TaskOutput,
};
use ppq_bert::coordinator::Session;
use ppq_bert::core::error::Result;
use ppq_bert::model::config::{BertConfig, TaskKind};
use ppq_bert::model::secure::GraphSpec;
use ppq_bert::model::weights::synth_input;
use ppq_bert::party::SessionCfg;
use ppq_bert::protocols::max::MaxStrategy;
use ppq_bert::transport::Phase;

/// Spawn a full 3-party deployment (real loopback sockets, one thread
/// per party process body) with the given serving knobs.
fn spawn_deployment(
    cfg: BertConfig,
    serve: ServeOpts,
) -> ([String; 3], [u8; 16], Vec<JoinHandle<Result<()>>>) {
    let listeners: Vec<TcpListener> =
        (0..3).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
    let addrs: [String; 3] = listeners
        .iter()
        .map(|l| l.local_addr().unwrap().to_string())
        .collect::<Vec<_>>()
        .try_into()
        .unwrap();
    let session = deployment_session_id(
        SessionCfg::default().master_seed,
        &cfg,
        &served_keys(&serve, &cfg),
    );
    let mut handles = Vec::new();
    for (id, listener) in listeners.into_iter().enumerate() {
        let mut opts = PartyOpts::new(id, cfg);
        opts.serve = serve.clone();
        for p in 0..3 {
            if p != id {
                opts.peers[p] = Some(addrs[p].clone());
            }
        }
        handles.push(std::thread::spawn(move || run_party(listener, opts)));
    }
    (addrs, session, handles)
}

/// THE acceptance pin: 4 concurrent loopback-TCP clients receive logits
/// bit-identical to sequential submission of the same window through an
/// in-process session, the party-side window count drops below 4
/// (cross-client batching actually engaged), and the merged per-party
/// meters equal the in-process meter per directed link and per phase.
#[test]
fn four_concurrent_clients_batch_into_one_window_matching_in_process() {
    let cfg = BertConfig::tiny();
    let serve = ServeOpts {
        max_batch: 4,
        linger: Duration::from_secs(5),
        ..ServeOpts::default()
    };
    let (addrs, session, handles) = spawn_deployment(cfg, serve);

    // Connect all 4 clients first (so submissions race only the linger,
    // not the dial path), then submit simultaneously. Every client
    // blocks in wait() while the others are still outstanding; the
    // batcher cuts ONE window the moment the 4th request is admitted.
    let barrier = Arc::new(Barrier::new(4));
    let (tx, rx) = mpsc::channel();
    let mut clients = Vec::new();
    for k in 0..4usize {
        let addrs = addrs.clone();
        let barrier = Arc::clone(&barrier);
        let tx = tx.clone();
        clients.push(std::thread::spawn(move || {
            let mut client =
                RemoteClient::connect(&addrs, session, Duration::from_secs(30)).expect("connect");
            barrier.wait();
            let x = synth_input(&cfg, 200 + k as u64);
            let id = client.submit(&x).expect("submit");
            let done = client.wait(id).expect("wait");
            tx.send((k, done)).unwrap();
        }));
    }
    drop(tx);
    let mut completed: Vec<(usize, Completed)> = rx.iter().collect();
    for c in clients {
        c.join().expect("client thread");
    }
    assert_eq!(completed.len(), 4);

    // Batching actually happened: fewer windows than clients.
    let mut probe =
        RemoteClient::connect(&addrs, session, Duration::from_secs(30)).expect("probe");
    let stats = probe.stats(1).expect("stats");
    assert_eq!(stats.served, 4);
    assert!(stats.windows < 4, "expected cross-client batching, got {} windows", stats.windows);
    assert_eq!(stats.windows, 1, "pre-connected clients under a long linger share one window");
    for (k, c) in &completed {
        assert_eq!(c.batch(), 4, "client {k}");
        assert_eq!(c.wid(), 0, "client {k}");
        // the window's amortized metrics reached every client
        assert!(c.window_online_rounds() > 0 && c.window_online_bytes() > 0, "client {k}");
    }
    let merged = probe.snapshot().expect("metrics");

    // Replay the window's exact composition through an in-process
    // session: requests submitted sequentially, evaluated as one
    // window. Logits must be BIT-identical and the meter must match.
    completed.sort_by_key(|(_, c)| (c.wid(), c.pos()));
    let (w, _) = prepared_model(cfg);
    let sess = Session::start(cfg, w, SessionCfg::default(), MaxStrategy::Tournament);
    let inputs: Vec<Vec<i64>> =
        completed.iter().map(|(k, _)| synth_input(&cfg, 200 + *k as u64)).collect();
    let replay = sess.infer_batch(&inputs);
    for (i, (k, c)) in completed.iter().enumerate() {
        assert_eq!(
            c.logits, replay[i],
            "client {k}: concurrent wire-path logits diverged from sequential in-process"
        );
    }
    let local = sess.snapshot();
    sess.shutdown();
    assert_eq!(merged.bytes, local.bytes, "per-link bytes diverged from in-process");
    assert_eq!(merged.msgs, local.msgs, "per-link messages diverged from in-process");
    assert_eq!(merged.rounds, local.rounds, "per-party rounds diverged from in-process");
    assert!(merged.total_bytes(Phase::Online) > 0);

    probe.shutdown().expect("shutdown");
    for h in handles {
        h.join().expect("party thread").expect("party error");
    }
}

/// Backpressure: overflowing the bounded admission queue is refused
/// with a clean per-request `Refused` frame naming the reason; the
/// refused request never reaches P0/P2 at all (single admission point —
/// refusal is symmetric by construction), and the deployment — and the
/// refused client's own connection — keep serving afterwards.
#[test]
fn queue_overflow_is_refused_cleanly_and_deployment_survives() {
    let cfg = BertConfig::tiny();
    let serve = ServeOpts {
        max_batch: 8,
        linger: Duration::from_millis(1500),
        queue_cap: 2,
        max_inflight: 64,
        ..ServeOpts::default()
    };
    let (addrs, session, handles) = spawn_deployment(cfg, serve);
    let mut client =
        RemoteClient::connect(&addrs, session, Duration::from_secs(30)).expect("connect");
    let x = synth_input(&cfg, 300);

    // Three rapid submissions: two fill the queue (cap 2) and linger;
    // the third must bounce off the full queue.
    let id1 = client.submit(&x).expect("submit 1");
    let id2 = client.submit(&x).expect("submit 2");
    let id3 = client.submit(&x).expect("submit 3");
    let err = client.wait(id3).unwrap_err();
    assert!(err.to_string().contains("queue full"), "{err}");

    // The admitted window still completes for the first two...
    let d1 = client.wait(id1).expect("wait 1");
    let d2 = client.wait(id2).expect("wait 2");
    assert_eq!((d1.batch(), d2.batch()), (2, 2));

    // ...and the refusal stayed local to P1: P0/P2 saw exactly the one
    // served window, nothing else.
    let s1 = client.stats(1).expect("stats p1");
    assert_eq!((s1.windows, s1.served, s1.refused), (1, 2, 1));
    for p in [0usize, 2] {
        let s = client.stats(p).expect("stats");
        assert_eq!((s.windows, s.served, s.refused), (1, 2, 0), "party {p}");
    }

    // The same connection keeps working after its refusal.
    let again = client.infer(&x).expect("deployment still serving after refusal");
    assert_eq!(again.len(), cfg.n_classes);

    client.shutdown().expect("shutdown");
    for h in handles {
        h.join().expect("party thread").expect("party error");
    }
}

/// Backpressure: the per-connection in-flight cap refuses cleanly and
/// the capacity is released once the window completes.
#[test]
fn per_connection_inflight_cap_refuses_cleanly() {
    let cfg = BertConfig::tiny();
    let serve = ServeOpts {
        max_batch: 8,
        linger: Duration::from_millis(1500),
        queue_cap: 64,
        max_inflight: 1,
        ..ServeOpts::default()
    };
    let (addrs, session, handles) = spawn_deployment(cfg, serve);
    let mut client =
        RemoteClient::connect(&addrs, session, Duration::from_secs(30)).expect("connect");
    let x = synth_input(&cfg, 310);

    let id1 = client.submit(&x).expect("submit 1");
    let id2 = client.submit(&x).expect("submit 2");
    let err = client.wait(id2).unwrap_err();
    assert!(err.to_string().contains("in flight"), "{err}");
    let d1 = client.wait(id1).expect("wait 1");
    assert_eq!(d1.batch(), 1);

    // In-flight budget released on completion: the next request serves.
    let again = client.infer(&x).expect("capacity released after completion");
    assert_eq!(again.len(), cfg.n_classes);

    client.shutdown().expect("shutdown");
    for h in handles {
        h.join().expect("party thread").expect("party error");
    }
}

/// The `--check`-style replay contract around a disruption: when one
/// request in a pipelined stream is refused, the COMPLETED requests'
/// window compositions still replay bit-identically through an
/// in-process session — a refusal never shifts, reorders, or
/// contaminates the windows around it. (The crash-induced variant of
/// this case — a real `kill -9` fault via `repro loadgen --fault` —
/// lives in fault_tests.rs, which drives actual processes.)
#[test]
fn completed_requests_around_a_refusal_replay_bit_identically() {
    let cfg = BertConfig::tiny();
    let serve = ServeOpts {
        max_batch: 1,
        linger: Duration::from_millis(20),
        queue_cap: 1,
        max_inflight: 64,
        ..ServeOpts::default()
    };
    let (addrs, session, handles) = spawn_deployment(cfg, serve);
    let mut client =
        RemoteClient::connect(&addrs, session, Duration::from_secs(30)).expect("connect");

    // Rapid-fire submissions against a single-slot queue: some are
    // admitted (each its own window, max_batch 1), at least one bounces.
    let inputs: Vec<Vec<i64>> = (0..4).map(|i| synth_input(&cfg, 600 + i as u64)).collect();
    let ids: Vec<u64> = inputs.iter().map(|x| client.submit(x).expect("submit")).collect();
    let mut completed: Vec<(usize, Completed)> = Vec::new();
    let mut refused = 0usize;
    for (ridx, id) in ids.into_iter().enumerate() {
        match client.wait(id) {
            Ok(done) => completed.push((ridx, done)),
            Err(e) => {
                assert!(e.to_string().contains("refused"), "unexpected failure: {e}");
                refused += 1;
            }
        }
    }
    assert!(refused >= 1, "the single-slot queue should have refused at least one request");
    assert!(!completed.is_empty(), "some requests must have completed around the refusal");

    // Replay the completed windows, in window order, through a fresh
    // in-process session: logits must be bit-identical.
    completed.sort_by_key(|(_, c)| (c.wid(), c.pos()));
    let (w, _) = prepared_model(cfg);
    let sess = Session::start(cfg, w, SessionCfg::default(), MaxStrategy::Tournament);
    for (ridx, c) in &completed {
        let replay = sess.infer_batch(std::slice::from_ref(&inputs[*ridx]));
        assert_eq!(c.logits, replay[0], "request {ridx} diverged from the in-process replay");
    }
    sess.shutdown();

    client.shutdown().expect("shutdown");
    for h in handles {
        h.join().expect("party thread").expect("party error");
    }
}

/// A mid-stream client disconnect drops ONLY that client's queued
/// requests: its window slot is reclaimed before the cut (the next
/// window holds exactly the surviving client's work), the deployment
/// keeps serving, and the surviving requests' logits still match an
/// in-process window of the same composition bit-for-bit.
#[test]
fn client_disconnect_drops_only_its_requests() {
    let cfg = BertConfig::tiny();
    let serve = ServeOpts {
        max_batch: 8,
        linger: Duration::from_millis(2500),
        queue_cap: 64,
        max_inflight: 64,
        ..ServeOpts::default()
    };
    let (addrs, session, handles) = spawn_deployment(cfg, serve);

    // Client A submits one request, then vanishes while its window is
    // still lingering.
    let mut a = RemoteClient::connect(&addrs, session, Duration::from_secs(30)).expect("connect a");
    a.submit(&synth_input(&cfg, 400)).expect("submit a");
    drop(a);
    // Give the party reader threads a moment to observe the EOF.
    std::thread::sleep(Duration::from_millis(400));

    let mut b = RemoteClient::connect(&addrs, session, Duration::from_secs(30)).expect("connect b");
    let xb1 = synth_input(&cfg, 401);
    let xb2 = synth_input(&cfg, 402);
    let id1 = b.submit(&xb1).expect("submit b1");
    let id2 = b.submit(&xb2).expect("submit b2");
    let d1 = b.wait(id1).expect("wait b1");
    let d2 = b.wait(id2).expect("wait b2");

    // A's slot was reclaimed before the cut: the one window that ran
    // holds exactly B's two requests.
    assert_eq!((d1.batch(), d2.batch()), (2, 2));
    assert_eq!(d1.wid(), 0);
    assert_eq!((d1.pos(), d2.pos()), (0, 1));
    let s1 = b.stats(1).expect("stats");
    assert_eq!((s1.windows, s1.served), (1, 2));

    // Bit-for-bit parity with the same composition in-process.
    let (w, _) = prepared_model(cfg);
    let sess = Session::start(cfg, w, SessionCfg::default(), MaxStrategy::Tournament);
    let replay = sess.infer_batch(&[xb1, xb2]);
    sess.shutdown();
    assert_eq!(d1.logits, replay[0]);
    assert_eq!(d2.logits, replay[1]);

    b.shutdown().expect("shutdown");
    for h in handles {
        h.join().expect("party thread").expect("party error");
    }
}

/// The heterogeneous-serving acceptance pin (see DESIGN.md
/// §Heterogeneous serving): ONE deployment concurrently serves three task heads at two
/// seq-length buckets. Requests land in the smallest served bucket that
/// fits their true length, windows are cut strictly per (task, bucket)
/// (never mixed), the prefilled per-key tapes serve each key's first
/// full window with ZERO request-path offline bytes, every output is
/// bit-identical to a fresh single-task in-process session evaluating
/// the identically padded composition, and hostile or mismatched
/// requests are refused with clear errors while the same connection
/// keeps serving.
#[test]
fn mixed_traffic_windows_never_mix_buckets_and_replay_per_task() {
    let cfg = BertConfig::tiny(); // seq_len 8: buckets 4 and 8 both valid
    let serve = ServeOpts {
        max_batch: 2,
        linger: Duration::from_secs(3),
        prep_depth: 2, // >= 1 tape per (task, bucket) key at prefill
        tasks: vec![TaskKind::Classify, TaskKind::Ner, TaskKind::Embed],
        buckets: vec![4, 8],
        ..ServeOpts::default()
    };
    let (addrs, session, handles) = spawn_deployment(cfg, serve);
    let mut client =
        RemoteClient::connect(&addrs, session, Duration::from_secs(30)).expect("connect");

    // Admission refuses mismatched requests with a clear reason, and
    // the connection keeps working afterwards (refusals stay local to
    // P1 — no other party ever learns about them).
    let in4 = |seed: u64| synth_input(&BertConfig { seq_len: 4, ..cfg }, seed);
    let err = client
        .infer_request(&InferenceRequest::new(TaskKind::Pair, 4, in4(900)))
        .unwrap_err();
    assert!(err.to_string().contains("not served by this deployment"), "{err}");
    let err = client
        .infer_request(&InferenceRequest::new(TaskKind::Classify, 3, in4(901)))
        .unwrap_err();
    assert!(err.to_string().contains("claims sequence length"), "{err}");
    let long = synth_input(&BertConfig { seq_len: 16, ..cfg }, 902);
    let err = client.infer_request(&InferenceRequest::new(TaskKind::Ner, 16, long)).unwrap_err();
    assert!(err.to_string().contains("exceeds every served bucket"), "{err}");

    // Mixed pipelined stream: (task, true length) pairs across both
    // buckets; true lengths 3 and 6 exercise the zero-padding path.
    let reqs: [(TaskKind, usize, u64); 6] = [
        (TaskKind::Classify, 4, 910),
        (TaskKind::Classify, 4, 911), // same key, adjacent: shares a window
        (TaskKind::Ner, 3, 912),      // padded to s4
        (TaskKind::Embed, 8, 913),
        (TaskKind::Classify, 6, 914), // padded to s8
        (TaskKind::Ner, 8, 915),
    ];
    let ids: Vec<u64> = reqs
        .iter()
        .map(|&(t, len, seed)| {
            let x = synth_input(&BertConfig { seq_len: len, ..cfg }, seed);
            client.submit_request(&InferenceRequest::new(t, len, x)).expect("submit")
        })
        .collect();
    let completed: Vec<(usize, Completed)> =
        ids.into_iter().enumerate().map(|(i, id)| (i, client.wait(id).expect("wait"))).collect();

    // Every request landed in the smallest served bucket that fits its
    // true length, under its own task, with a task-shaped output.
    for (i, c) in &completed {
        let (task, len, _) = reqs[*i];
        let want_bucket = if len <= 4 { 4 } else { 8 };
        assert_eq!(c.bucket(), want_bucket, "request {i} landed in the wrong bucket");
        assert_eq!(TaskKind::from_u8(c.task()).unwrap(), task, "request {i} task");
        assert_eq!(
            c.logits.len(),
            task.out_len(&cfg, want_bucket),
            "request {i}: output not shaped for {} at s{want_bucket}",
            task.as_str()
        );
    }

    // Windows never mix (task, bucket) keys.
    let mut by_window: BTreeMap<u64, Vec<(usize, Completed)>> = BTreeMap::new();
    for (i, c) in completed {
        by_window.entry(c.wid()).or_default().push((i, c));
    }
    for (wid, members) in &by_window {
        let key = (members[0].1.task(), members[0].1.bucket());
        for (i, c) in members {
            assert_eq!((c.task(), c.bucket()), key, "window {wid} mixed keys at request {i}");
        }
    }

    // The prefill put one max_batch tape behind every served key, so a
    // FULL window consumes warm material: zero request-path offline
    // bytes. (Partial windows are cut at sizes that were never prepped
    // and regenerate inline — only full windows are asserted.)
    let mut saw_full = false;
    for members in by_window.values() {
        if members[0].1.batch() == 2 {
            saw_full = true;
            assert_eq!(
                members[0].1.window_offline_bytes(),
                0,
                "full window of a prefilled key must serve warm"
            );
        }
    }
    assert!(saw_full, "the adjacent classify.s4 pair should have shared a full window");

    // Per-key replay: each window's padded composition through a fresh
    // single-task in-process session of that exact GraphSpec must be
    // bit-identical.
    let mut groups: BTreeMap<(u8, usize), Vec<u64>> = BTreeMap::new();
    for (wid, members) in &by_window {
        groups.entry((members[0].1.task(), members[0].1.bucket())).or_default().push(*wid);
    }
    for ((task_byte, bucket), wids) in &groups {
        let task = TaskKind::from_u8(*task_byte).unwrap();
        let spec = GraphSpec::new(task, cfg).with_seq(*bucket);
        let (w, _) = prepared_model(cfg);
        let sess = Session::start_spec(spec, w, SessionCfg::default());
        for wid in wids {
            let mut members: Vec<&(usize, Completed)> = by_window[wid].iter().collect();
            members.sort_by_key(|(_, c)| c.pos());
            let inputs: Vec<Vec<i64>> = members
                .iter()
                .map(|(i, _)| {
                    let (_, len, seed) = reqs[*i];
                    let x = synth_input(&BertConfig { seq_len: len, ..cfg }, seed);
                    pad_to_bucket(x, *bucket, cfg.d_model)
                })
                .collect();
            let outs = sess.infer_batch(&inputs);
            for ((i, c), l) in members.iter().zip(&outs) {
                assert_eq!(
                    &c.logits, l,
                    "request {i} (window {wid}) diverged from the single-task replay"
                );
            }
        }
        sess.shutdown();
    }

    // The typed client API round-trips a task-shaped response.
    let resp = client
        .infer_request(&InferenceRequest::new(TaskKind::Embed, 4, in4(920)))
        .expect("typed embed request");
    assert!(matches!(resp.output, TaskOutput::Hidden(_)));
    assert_eq!(resp.output.values().len(), cfg.d_model);

    client.shutdown().expect("shutdown");
    for h in handles {
        h.join().expect("party thread").expect("party error");
    }
}
