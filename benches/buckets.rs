//! Heterogeneous-workload sweep: one warm window per (task, bucket)
//! key, the unit the multi-task sequencer schedules
//! (DESIGN.md §Heterogeneous serving).
//!
//! For every task head (classify / ner / pair / embed) at two padded
//! sequence-length buckets, a fresh session preps the bucket's tape and
//! serves one window. The recorded rows pin the per-bucket cost
//! trajectory (`buckets/{task}/s{seq}`): warm windows must spend ZERO
//! request-path offline bytes regardless of task or bucket, online
//! rounds are constant per bucket (not per request mix), and shorter
//! buckets are strictly cheaper in online bytes — the saving that
//! bucketing buys over padding everything to the longest sequence.
//!
//!   cargo bench --bench buckets
//!   cargo bench --bench buckets -- --quick --json BENCH_ci.json   (CI smoke)

use ppq_bert::bench_harness::{fmt_dur, prepared_inputs, prepared_model, BenchOpts, Table};
use ppq_bert::coordinator::Session;
use ppq_bert::model::config::{BertConfig, TaskKind};
use ppq_bert::model::secure::GraphSpec;
use ppq_bert::party::SessionCfg;
use ppq_bert::transport::{NetParams, Phase};

const TASKS: [TaskKind; 4] =
    [TaskKind::Classify, TaskKind::Ner, TaskKind::Pair, TaskKind::Embed];

fn main() {
    let opts = BenchOpts::from_env_args();
    let cfg = BertConfig::tiny();
    let buckets: [usize; 2] = [cfg.seq_len / 2, cfg.seq_len];
    let batch = if opts.quick { 1 } else { 4 };

    let mut t = Table::new(&[
        "task",
        "bucket",
        "warm offline B",
        "online rounds",
        "online KiB",
        "LAN window",
        "WAN window",
    ]);

    for task in TASKS {
        let mut bytes_by_bucket = Vec::new();
        for &bucket in &buckets {
            // Fresh session per (task, bucket) key: exactly what the
            // deployment's sequencer keeps warm independently per key.
            let (w, _) = prepared_model(cfg);
            let spec = GraphSpec::new(task, cfg).with_seq(bucket).with_batch(batch);
            let bucket_cfg = spec.effective();
            let sess = Session::start_spec(spec, w, SessionCfg::default());
            sess.prep(batch);
            let pre = sess.snapshot();
            let t0 = std::time::Instant::now();
            let outs = sess.infer_batch(&prepared_inputs(&bucket_cfg, batch));
            let wall = t0.elapsed();
            assert_eq!(outs.len(), batch);
            let mut d = sess.snapshot();
            d.saturating_sub_assign(&pre);
            sess.shutdown();

            let offline = d.total_bytes(Phase::Offline);
            assert_eq!(
                offline, 0,
                "{}/s{bucket}: a prepped bucket must serve warm",
                task.as_str()
            );
            let online = d.total_bytes(Phase::Online);
            let rounds = d.max_rounds(Phase::Online);
            bytes_by_bucket.push(online);
            opts.record(&format!("buckets/{}/s{bucket}", task.as_str()), wall, online, rounds);
            t.row(vec![
                task.as_str().to_string(),
                format!("s{bucket}"),
                offline.to_string(),
                rounds.to_string(),
                format!("{:.1}", online as f64 / 1024.0),
                fmt_dur(NetParams::LAN.modeled_phase_time(&d, Phase::Online)),
                fmt_dur(NetParams::WAN.modeled_phase_time(&d, Phase::Online)),
            ]);
        }
        assert!(
            bytes_by_bucket[0] < bytes_by_bucket[1],
            "{}: the short bucket must be strictly cheaper online ({} !< {})",
            task.as_str(),
            bytes_by_bucket[0],
            bytes_by_bucket[1]
        );
    }
    t.print(
        "per-(task, bucket) warm windows: zero request-path offline bytes at every key; \
         short buckets cost strictly fewer online bytes than padding to the full sequence \
         (BERT-tiny; window = batch)",
    );
}
