//! Measured thread sweep of the persistent worker pool: offline tape
//! generation and the warm online window, each at worker-pool sizes
//! 1/2/4/8 on the same machine (DESIGN.md §Parallel runtime).
//!
//! Thread count must change wall-clock ONLY — the bench asserts P1's
//! logits are bit-identical across the sweep — and records measured
//! walls as `threads/t{N}/{offline,online}` rows. The Amdahl curve from
//! [`ppq_bert::bench_harness::thread_scale`] (formerly the only source
//! of thread-sweep numbers, DESIGN.md §Substitutions) is kept as a
//! modeled cross-check column next to the measurements.
//!
//!   cargo bench --bench threads
//!   CI smoke: cargo bench --bench threads -- --quick --json BENCH_ci.json

use std::sync::{mpsc, Arc, Barrier};
use std::time::Instant;

use ppq_bert::bench_harness::{
    fmt_dur, prepared_inputs, prepared_model, thread_scale, BenchOpts, Table,
};
use ppq_bert::coordinator::session::{prep_into_pool, serve_window};
use ppq_bert::model::config::{BertConfig, TaskKind};
use ppq_bert::model::secure::GraphSpec;
use ppq_bert::party::{PartyCtx, SessionCfg, P0, P1};
use ppq_bert::protocols::tape_store::TapePool;
use ppq_bert::transport::{build_mesh, Metrics, Phase};

const THREADS: [usize; 4] = [1, 2, 4, 8];

fn main() {
    let opts = BenchOpts::from_env_args();
    let cfg = BertConfig::tiny();
    let batch = if opts.quick { 1 } else { 4 };
    let (weights, _) = prepared_model(cfg);
    let weights = Arc::new(weights);
    let inputs = prepared_inputs(&cfg, batch);

    let mut t = Table::new(&[
        "threads",
        "offline wall",
        "offline x",
        "online wall",
        "online x",
        "modeled x (Amdahl)",
    ]);
    let mut ref_walls: Option<(f64, f64)> = None;
    let mut ref_logits: Option<Vec<Vec<i64>>> = None;
    for threads in THREADS {
        let scfg = SessionCfg { threads, ..SessionCfg::default() };
        let metrics = Arc::new(Metrics::new());
        let nets = build_mesh(Arc::clone(&metrics), None);
        // Main thread is the timer; the barrier brackets the offline and
        // online regions so setup (weight sharing, graph build) is
        // excluded from both walls.
        let barrier = Arc::new(Barrier::new(4));
        let (tx, rx) = mpsc::channel();
        let mut parties = Vec::new();
        for (id, net) in nets.into_iter().enumerate() {
            let weights = Arc::clone(&weights);
            let inputs = inputs.clone();
            let barrier = Arc::clone(&barrier);
            let tx = tx.clone();
            parties.push(std::thread::spawn(move || {
                let ctx = PartyCtx::new(id, net, scfg.master_seed, scfg.threads);
                let w = if id == P0 { Some(&*weights) } else { None };
                let model = GraphSpec::new(TaskKind::Classify, cfg).build(&ctx, w);
                let mut pool = TapePool::new();
                barrier.wait(); // offline timer starts
                prep_into_pool(&ctx, &model, &mut pool, batch);
                barrier.wait(); // offline timer stops
                let p1_inputs = if id == P1 { Some(&inputs[..]) } else { None };
                barrier.wait(); // online timer starts
                let logits = serve_window(&ctx, &model, &mut pool, batch, p1_inputs);
                barrier.wait(); // online timer stops
                ctx.flush_timer();
                if id == P1 {
                    let _ = tx.send(logits);
                }
            }));
        }
        barrier.wait();
        let t0 = Instant::now();
        barrier.wait();
        let offline_wall = t0.elapsed();
        barrier.wait();
        let t1 = Instant::now();
        barrier.wait();
        let online_wall = t1.elapsed();
        for h in parties {
            h.join().expect("bench party");
        }
        let logits = rx.recv().expect("P1 logits");
        match &ref_logits {
            None => ref_logits = Some(logits),
            Some(want) => {
                assert_eq!(&logits, want, "T={threads}: logits must be thread-invariant");
            }
        }
        let d = metrics.snapshot();
        opts.record(
            &format!("threads/t{threads}/offline"),
            offline_wall,
            d.total_bytes(Phase::Offline),
            d.max_rounds(Phase::Offline),
        );
        opts.record(
            &format!("threads/t{threads}/online"),
            online_wall,
            d.total_bytes(Phase::Online),
            d.max_rounds(Phase::Online),
        );
        let (off_s, on_s) = (offline_wall.as_secs_f64(), online_wall.as_secs_f64());
        let (ref_off, ref_on) = *ref_walls.get_or_insert((off_s, on_s));
        t.row(vec![
            threads.to_string(),
            fmt_dur(offline_wall),
            format!("{:.2}", ref_off / off_s.max(1e-9)),
            fmt_dur(online_wall),
            format!("{:.2}", ref_on / on_s.max(1e-9)),
            format!("{:.2}", thread_scale(threads)),
        ]);
    }
    t.print(&format!(
        "measured thread sweep (BERT-tiny, window = {batch}): one persistent worker pool per \
         party drives matmul rows, attention blocks, packing and offline PRG generation; \
         speedups are measured on this machine, the Amdahl column is the calibrated model \
         kept as a cross-check (DESIGN.md §Parallel runtime)",
    ));
}
