//! Table 4 reproduction: communication cost (MB) vs CrypTen and Sigma for
//! 8/16/32/64 tokens.
//!
//! Paper row: tokens 8: ours 4.43 online / 29.20 offline; CrypTen 3921;
//! Sigma 43.28 — ours online is *metered bytes* from the transport (exact,
//! not estimated); comparators from their published figures (same source
//! as the paper) plus our own CrypTen-style implementation metered on the
//! tiny config as a sanity anchor.
//!
//!   cargo bench --bench table4

use ppq_bert::baselines::sigma;
use ppq_bert::bench_harness::{prepared_model, Table};
use ppq_bert::coordinator::{Coordinator, ServerConfig};
use ppq_bert::model::config::BertConfig;
use ppq_bert::transport::Phase;

fn main() {
    let mut t = Table::new(&[
        "tokens",
        "ours online MB",
        "ours offline MB",
        "CrypTen MB (pub)",
        "Sigma MB (pub)",
        "online vs Sigma",
    ]);
    let crypten_pub = [(8, 3921.0), (16, 8342.0), (32, 21114.0), (64, 63731.0)];

    // Measure a reduced-depth model and scale comm linearly in layers
    // (comm is exactly layer-homogeneous: every layer ships the same
    // table/conversion volume; verified by the layer-scaling test).
    let measured_layers = 2usize;
    let layer_scale = 12.0 / measured_layers as f64;
    for (i, tokens) in [8usize, 16, 32, 64].iter().enumerate() {
        let cfg = BertConfig::base_with_seq(*tokens).with_layers(measured_layers);
        let (w, x) = prepared_model(cfg);
        let mut coord = Coordinator::start(ServerConfig::new(cfg), w);
        coord.submit(x);
        let _ = coord.run_batch();
        let s = coord.snapshot();
        coord.shutdown();
        let online = s.total_mb(Phase::Online) * layer_scale;
        let offline = s.total_mb(Phase::Offline) * layer_scale;
        let sg = sigma::comm_mb(*tokens);
        t.row(vec![
            tokens.to_string(),
            format!("{online:.2}"),
            format!("{offline:.2}"),
            format!("{:.0}", crypten_pub[i].1),
            format!("{sg:.2}"),
            format!("{:.1}x", sg / online),
        ]);
    }
    t.print("Table 4: communication (paper: ours 4.43/8.87/17.80/35.83 MB online, 29.2/59.3/122.5/260.0 offline; 9.8-11.8x less online than Sigma)");
}
