//! Replica fleet sweep (DESIGN.md §Replica fleet): the same client
//! load through a fleet of 1 and then 2 replica trios, all in-process
//! on loopback TCP — the router redirect path, sticky assignments, and
//! per-replica meshes are all real, only the processes are threads.
//!
//! Recorded rows pin the fleet's perf trajectory in BENCH_ci.json:
//! `fleet/r{R}/throughput` (aggregate wall for the whole load) and
//! `fleet/r{R}/p99` (p99 of the per-request window walls reported by
//! each replica's P1). The bench also pins the fleet's correctness
//! claim: every client submits the SAME request stream, so replicas
//! with DIFFERENT master seeds must reveal bit-identical logits —
//! spreading load across trios never perturbs outputs.
//!
//!   cargo bench --bench fleet
//!   CI smoke: cargo bench --bench fleet -- --quick --json BENCH_ci.json

use std::net::TcpListener;
use std::sync::{mpsc, Arc, Barrier};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use ppq_bert::bench_harness::{fmt_dur, BenchOpts, Table};
use ppq_bert::coordinator::fleet::{
    halt_fleet, run_fleet_router, FleetClient, FleetOpts, ReplicaSpec,
};
use ppq_bert::coordinator::remote::{
    run_party, seed_from_label, served_keys, InferenceRequest, PartyOpts, ServeOpts,
};
use ppq_bert::core::error::Result;
use ppq_bert::model::config::{BertConfig, TaskKind};
use ppq_bert::model::weights::synth_input;
use ppq_bert::party::P1;

/// Spawn one replica trio under its fleet label (one thread per party).
fn spawn_replica(
    cfg: BertConfig,
    serve: &ServeOpts,
    label: &str,
) -> ([String; 3], Vec<JoinHandle<Result<()>>>) {
    let listeners: Vec<TcpListener> =
        (0..3).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
    let addrs: [String; 3] = listeners
        .iter()
        .map(|l| l.local_addr().unwrap().to_string())
        .collect::<Vec<_>>()
        .try_into()
        .unwrap();
    let mut handles = Vec::new();
    for (id, listener) in listeners.into_iter().enumerate() {
        let mut opts = PartyOpts::new(id, cfg);
        opts.serve = serve.clone();
        opts.scfg.master_seed = seed_from_label(label);
        for p in 0..3 {
            if p != id {
                opts.peers[p] = Some(addrs[p].clone());
            }
        }
        handles.push(std::thread::spawn(move || run_party(listener, opts)));
    }
    (addrs, handles)
}

fn main() {
    let opts = BenchOpts::from_env_args();
    let cfg = BertConfig::tiny();
    let per_client = if opts.quick { 2 } else { 8 };
    let serve = ServeOpts::default();
    let keys = served_keys(&serve, &cfg);

    let mut t = Table::new(&[
        "replicas",
        "clients",
        "requests",
        "total wall",
        "req/s",
        "window p50",
        "window p99",
    ]);
    let mut ref_logits: Option<Vec<Vec<Vec<i64>>>> = None;
    let mut rates = Vec::new();
    for replicas in [1usize, 2] {
        // R trios + the router; 2 clients per replica drive the load.
        let mut party_handles = Vec::new();
        let mut specs = Vec::new();
        for r in 0..replicas {
            let label = format!("fleet-r{r}");
            let (addrs, handles) = spawn_replica(cfg, &serve, &label);
            party_handles.extend(handles);
            specs.push(ReplicaSpec { label, addrs });
        }
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let router = listener.local_addr().unwrap().to_string();
        let fopts = FleetOpts {
            replicas: specs,
            cfg,
            keys: keys.clone(),
            poll: Duration::from_millis(100),
            timeout: Duration::from_secs(30),
        };
        let router_handle = std::thread::spawn(move || run_fleet_router(listener, fopts));

        let clients = 2 * replicas;
        let barrier = Arc::new(Barrier::new(clients + 1));
        let (tx, rx) = mpsc::channel();
        let mut workers = Vec::new();
        for k in 0..clients {
            let router = router.clone();
            let keys = keys.clone();
            let barrier = Arc::clone(&barrier);
            let tx = tx.clone();
            workers.push(std::thread::spawn(move || {
                let mut fc = FleetClient::connect(&router, &cfg, &keys, Duration::from_secs(30))
                    .expect("fleet connect");
                barrier.wait();
                let mut walls = Vec::with_capacity(per_client);
                let mut logits = Vec::with_capacity(per_client);
                for i in 0..per_client {
                    // The SAME stream for every client: replicas with
                    // different seeds must agree bit-for-bit.
                    let x = synth_input(&cfg, 700 + i as u64);
                    let req = InferenceRequest::new(TaskKind::Classify, cfg.seq_len, x);
                    let resp = fc.client.infer_request(&req).expect("serve");
                    walls.push(resp.completed.reports[P1].wall_ns);
                    logits.push(resp.completed.logits.clone());
                }
                tx.send((k, walls, logits)).unwrap();
            }));
        }
        drop(tx);
        barrier.wait();
        let t0 = Instant::now();
        let mut results: Vec<(usize, Vec<u64>, Vec<Vec<i64>>)> = rx.iter().collect();
        let wall = t0.elapsed();
        for h in workers {
            h.join().expect("client thread");
        }
        results.sort_by_key(|(k, _, _)| *k);

        // Bit-identity across replicas AND across fleet sizes.
        let logits: Vec<Vec<Vec<i64>>> = results.iter().map(|(_, _, l)| l.clone()).collect();
        for (k, per) in logits.iter().enumerate() {
            assert_eq!(per, &logits[0], "client {k}: fleet spread perturbed logits");
        }
        match &ref_logits {
            None => ref_logits = Some(logits),
            Some(want) => {
                assert_eq!(&logits[0], &want[0], "r{replicas}: diverged from the r1 fleet");
            }
        }

        let total = clients * per_client;
        let mut walls: Vec<u64> = results.iter().flat_map(|(_, w, _)| w.iter().copied()).collect();
        walls.sort_unstable();
        let pct = |q: f64| -> Duration {
            Duration::from_nanos(walls[((walls.len() - 1) as f64 * q).round() as usize])
        };
        let rate = total as f64 / wall.as_secs_f64().max(1e-9);
        rates.push(rate);
        opts.record(&format!("fleet/r{replicas}/throughput"), wall, 0, total as u64);
        opts.record(&format!("fleet/r{replicas}/p99"), pct(0.99), 0, 0);
        t.row(vec![
            replicas.to_string(),
            clients.to_string(),
            total.to_string(),
            fmt_dur(wall),
            format!("{rate:.1}"),
            fmt_dur(pct(0.50)),
            fmt_dur(pct(0.99)),
        ]);

        halt_fleet(&router, &cfg, &keys, Duration::from_secs(30)).expect("fleet halt");
        router_handle.join().expect("router thread").expect("router exits cleanly");
        for h in party_handles {
            h.join().expect("party thread").expect("party exits cleanly");
        }
    }
    t.print(&format!(
        "fleet sweep (BERT-tiny, 2 clients/replica x {per_client} requests, r2/r1 speedup \
         {:.2}x): identical request streams through 1- and 2-replica fleets reveal \
         bit-identical logits; throughput and window-wall tails are recorded as the \
         fleet's perf trajectory (DESIGN.md §Replica fleet)",
        rates[1] / rates[0].max(1e-9)
    ));
}
