//! Transport backend micro-costs (beyond the paper): what does moving a
//! party message through the in-process mesh vs. loopback TCP cost, and
//! what does that do to an end-to-end tiny-model window? Quantifies the
//! overhead of deployability — protocol bytes/rounds are identical
//! across backends by construction (see rust/tests/transport_tests.rs),
//! so only wall-clock differs.
//!
//! Run: `cargo bench --bench transport`
//! CI smoke: `cargo bench --bench transport -- --quick --json BENCH_ci.json`

use std::sync::Arc;

use ppq_bert::bench_harness::{
    fmt_dur, prepared_inputs, prepared_model, time_once, BenchOpts, Table,
};
use ppq_bert::core::ring::R16;
use ppq_bert::model::config::{BertConfig, TaskKind};
use ppq_bert::model::passes::OptConfig;
use ppq_bert::model::secure::{secure_infer, secure_infer_batch, GraphSpec};
use ppq_bert::party::{PartyCtx, SessionCfg, P0, P1};
use ppq_bert::transport::{build_mesh, loopback_mesh, Metrics, Net, Phase};

/// One ping-pong exchange of `n` 16-bit ring elements between P1 and P2.
fn pingpong(nets: [Net; 3], n: usize, iters: usize) -> std::time::Duration {
    let [_n0, n1, n2] = nets;
    let vals: Vec<u64> = (0..n as u64).map(|v| v % 1000).collect();
    let mut out = std::time::Duration::ZERO;
    std::thread::scope(|s| {
        let v = vals.clone();
        s.spawn(move || {
            for _ in 0..iters {
                let _ = n2.exchange_ring(1, Phase::Online, R16, &v);
            }
        });
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            let _ = n1.exchange_ring(2, Phase::Online, R16, &vals);
        }
        out = t0.elapsed() / iters as u32;
    });
    out
}

/// Setup + one single-request inference over pre-built endpoints.
fn infer_over(nets: [Net; 3]) {
    let cfg = BertConfig::tiny();
    let (weights, x) = prepared_model(cfg);
    std::thread::scope(|s| {
        for net in nets {
            let (weights, x) = (&weights, &x);
            s.spawn(move || {
                let ctx = PartyCtx::new(net.id, net, SessionCfg::default().master_seed, 1);
                let model = GraphSpec::new(TaskKind::Classify, cfg)
                    .build(&ctx, (ctx.id == P0).then_some(weights));
                let xin = (ctx.id == P1).then(|| x.clone());
                let _ = secure_infer(&ctx, &model, xin.as_deref());
            });
        }
    });
}

/// Setup + one `batch`-item window sealed at an optimizer level.
fn infer_batch_over(nets: [Net; 3], batch: usize, opt: OptConfig) {
    let cfg = BertConfig::tiny();
    let (weights, _) = prepared_model(cfg);
    let inputs = prepared_inputs(&cfg, batch);
    std::thread::scope(|s| {
        for net in nets {
            let (weights, inputs) = (&weights, &inputs);
            s.spawn(move || {
                let ctx = PartyCtx::new(net.id, net, SessionCfg::default().master_seed, 1);
                let w = (ctx.id == P0).then_some(weights);
                let model = GraphSpec::new(TaskKind::Classify, cfg).with_opt(opt).build(&ctx, w);
                let xin = (ctx.id == P1).then(|| inputs.clone());
                let _ = secure_infer_batch(&ctx, &model, batch, xin.as_deref());
            });
        }
    });
}

fn main() {
    let opts = BenchOpts::from_env_args();
    let session = SessionCfg::default().master_seed;

    let sizes: &[usize] = if opts.quick { &[1, 1_000] } else { &[1, 1_000, 100_000] };
    let mut t = Table::new(&["exchange size", "mesh", "tcp loopback"]);
    for &n in sizes {
        let iters = if opts.quick {
            20
        } else if n >= 100_000 {
            20
        } else {
            200
        };
        let mesh_metrics = Arc::new(Metrics::new());
        let mesh_nets = build_mesh(Arc::clone(&mesh_metrics), None);
        let mesh_dur = pingpong(mesh_nets, n, iters);
        let snap = mesh_metrics.snapshot();
        opts.record(
            &format!("transport/pingpong_mesh_{n}"),
            mesh_dur,
            snap.total_bytes(Phase::Online) / iters as u64,
            1,
        );
        let tcp_metrics = Arc::new(Metrics::new());
        let tcp_nets =
            loopback_mesh(Arc::clone(&tcp_metrics), session, None).expect("loopback mesh");
        let tcp_dur = pingpong(tcp_nets, n, iters);
        let snap = tcp_metrics.snapshot();
        opts.record(
            &format!("transport/pingpong_tcp_{n}"),
            tcp_dur,
            snap.total_bytes(Phase::Online) / iters as u64,
            1,
        );
        t.row(vec![format!("{n} x u16"), fmt_dur(mesh_dur), fmt_dur(tcp_dur)]);
    }
    t.print("one exchange_ring round trip (P1 <-> P2, averaged over many iters)");

    let mut t = Table::new(&["end-to-end (tiny, 1 request)", "wall"]);
    {
        let metrics = Arc::new(Metrics::new());
        let nets = build_mesh(Arc::clone(&metrics), None);
        let wall = time_once(|| infer_over(nets));
        let snap = metrics.snapshot();
        opts.record(
            "transport/infer_mesh_tiny",
            wall,
            snap.total_bytes(Phase::Online),
            snap.max_rounds(Phase::Online),
        );
        t.row(vec!["mesh".into(), fmt_dur(wall)]);
    }
    {
        let metrics = Arc::new(Metrics::new());
        let nets = loopback_mesh(Arc::clone(&metrics), session, None).expect("loopback mesh");
        let wall = time_once(|| infer_over(nets));
        let snap = metrics.snapshot();
        opts.record(
            "transport/infer_tcp_tiny",
            wall,
            snap.total_bytes(Phase::Online),
            snap.max_rounds(Phase::Online),
        );
        t.row(vec!["tcp loopback".into(), fmt_dur(wall)]);
    }
    t.print("setup + secure_infer across backends (same bytes/rounds by construction)");

    // Optimizer speedup: the same tiny model served cold over the mesh
    // at --opt 0 vs --opt 1. Round packing fuses adjacent independent
    // LUT converts, so opt1 measures strictly fewer online rounds with
    // identical online bytes (rust/tests/opt_tests.rs pins the logits
    // bit-identical across the two levels).
    let mut t = Table::new(&["batch", "opt", "online rounds", "online MB", "wall"]);
    for &batch in &[1usize, 4] {
        let mut rounds = [0u64; 2];
        for level in [0u8, 1] {
            let opt = OptConfig::from_level(level);
            let metrics = Arc::new(Metrics::new());
            let nets = build_mesh(Arc::clone(&metrics), None);
            let wall = time_once(|| infer_batch_over(nets, batch, opt));
            let snap = metrics.snapshot();
            rounds[level as usize] = snap.max_rounds(Phase::Online);
            opts.record(
                &format!("transport/opt_speedup/b{batch}/opt{level}"),
                wall,
                snap.total_bytes(Phase::Online),
                snap.max_rounds(Phase::Online),
            );
            t.row(vec![
                batch.to_string(),
                format!("--opt {level}"),
                snap.max_rounds(Phase::Online).to_string(),
                format!("{:.2}", snap.total_bytes(Phase::Online) as f64 / 1048576.0),
                fmt_dur(wall),
            ]);
        }
        assert!(
            rounds[1] < rounds[0],
            "B={batch}: opt1 must measure strictly fewer online rounds ({} vs {})",
            rounds[1],
            rounds[0],
        );
    }
    t.print("optimizer speedup: --opt 1 packs adjacent LUT converts (same bytes, fewer rounds)");
}
