//! Transport backend micro-costs (beyond the paper): what does moving a
//! party message through the in-process mesh vs. loopback TCP cost, and
//! what does that do to an end-to-end tiny-model window? Quantifies the
//! overhead of deployability — protocol bytes/rounds are identical
//! across backends by construction (see rust/tests/transport_tests.rs),
//! so only wall-clock differs.
//!
//! Run: `cargo bench --bench transport`

use std::sync::Arc;

use ppq_bert::bench_harness::{fmt_dur, prepared_model, time_median, Table};
use ppq_bert::core::ring::R16;
use ppq_bert::model::config::BertConfig;
use ppq_bert::model::secure::{secure_infer, SecureBert};
use ppq_bert::party::{PartyCtx, SessionCfg, P0, P1};
use ppq_bert::transport::{build_mesh, loopback_mesh, Metrics, Net, Phase};

/// One ping-pong exchange of `n` 16-bit ring elements between P1 and P2.
fn pingpong(nets: [Net; 3], n: usize, iters: usize) -> std::time::Duration {
    let [_n0, n1, n2] = nets;
    let vals: Vec<u64> = (0..n as u64).map(|v| v % 1000).collect();
    let mut out = std::time::Duration::ZERO;
    std::thread::scope(|s| {
        let v = vals.clone();
        s.spawn(move || {
            for _ in 0..iters {
                let _ = n2.exchange_ring(1, Phase::Online, R16, &v);
            }
        });
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            let _ = n1.exchange_ring(2, Phase::Online, R16, &vals);
        }
        out = t0.elapsed() / iters as u32;
    });
    out
}

/// Setup + one single-request inference over pre-built endpoints.
fn infer_over(nets: [Net; 3]) {
    let cfg = BertConfig::tiny();
    let (weights, x) = prepared_model(cfg);
    std::thread::scope(|s| {
        for net in nets {
            let (weights, x) = (&weights, &x);
            s.spawn(move || {
                let ctx = PartyCtx::new(net.id, net, SessionCfg::default().master_seed, 1);
                let model = SecureBert::setup(&ctx, cfg, (ctx.id == P0).then_some(weights));
                let xin = (ctx.id == P1).then(|| x.clone());
                let _ = secure_infer(&ctx, &model, xin.as_deref());
            });
        }
    });
}

fn main() {
    let session = SessionCfg::default().master_seed;
    let mesh = || build_mesh(Arc::new(Metrics::new()), None);
    let tcp = || loopback_mesh(Arc::new(Metrics::new()), session, None).expect("loopback mesh");

    let mut t = Table::new(&["exchange size", "mesh", "tcp loopback"]);
    for &n in &[1usize, 1_000, 100_000] {
        let iters = if n >= 100_000 { 20 } else { 200 };
        t.row(vec![
            format!("{n} x u16"),
            fmt_dur(pingpong(mesh(), n, iters)),
            fmt_dur(pingpong(tcp(), n, iters)),
        ]);
    }
    t.print("one exchange_ring round trip (P1 <-> P2, median behavior over many iters)");

    let mut t = Table::new(&["end-to-end (tiny, 1 request)", "wall"]);
    t.row(vec!["mesh".into(), fmt_dur(time_median(3, || infer_over(mesh())))]);
    t.row(vec!["tcp loopback".into(), fmt_dur(time_median(3, || infer_over(tcp())))]);
    t.print("setup + secure_infer across backends (same bytes/rounds by construction)");
}
