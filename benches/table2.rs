//! Table 2 reproduction: end-to-end BERT-base latency (ms) vs CrypTen and
//! SIGMA under LAN, across thread counts.
//!
//! Paper row:  CrypTen-GPU 21551 | Sigma #4 12311 | Sigma-GPU 4668 |
//!             Ours #4 1315 | #20 1165 | #96 969
//!
//! Method on this single-core container (DESIGN.md §Substitutions #3):
//! our absolute number is measured single-thread wall-clock on a reduced
//! depth (layers scaled up linearly — FC/softmax cost is layer-homogeneous)
//! plus the LAN network model; thread sweeps apply the Amdahl curve
//! calibrated to the paper's own scaling. Comparators: CrypTen/SIGMA
//! published figures (the same source the paper compares against).
//!
//!   cargo bench --bench table2

use ppq_bert::baselines::sigma;
use ppq_bert::bench_harness::{prepared_model, thread_scale, time_once, Table};
use ppq_bert::coordinator::{Coordinator, ServerConfig};
use ppq_bert::model::config::BertConfig;
use ppq_bert::transport::{NetParams, Phase};

fn main() {
    // Measure: BERT-base width, 3 of 12 layers (then scale by 4x), seq =
    // the paper's Table-2 regime (128 tokens is their figure-5 max; Table 2
    // uses their default benchmark = 128; we use 32 and scale linearly in
    // tokens for the printed 128 estimate to keep the run short).
    let measured_layers = 3usize;
    let cfg = BertConfig::base_with_seq(32).with_layers(measured_layers);
    let (w, x) = prepared_model(cfg);
    let mut sc = ServerConfig::new(cfg);
    sc.net = NetParams::LAN;
    let mut coord = Coordinator::start(sc, w);
    coord.submit(x);
    let mut results = Vec::new();
    let d = time_once(|| {
        results = coord.run_batch();
    });
    let snap = coord.snapshot();
    let r = &results[0];
    let layer_scale = BertConfig::base().n_layers as f64 / measured_layers as f64;
    let online_1t_ms = r.online_modeled.as_secs_f64() * 1e3 * layer_scale;
    let offline_1t_ms = r.offline_modeled.as_secs_f64() * 1e3 * layer_scale;
    let e2e_1t_ms = online_1t_ms + offline_1t_ms;
    eprintln!(
        "measured: {measured_layers}-layer seq-32 base run {:.1}s (online {:.0} ms + offline {:.0} ms per 12 layers, 1 thread); rounds/infer={}",
        d.as_secs_f64(),
        online_1t_ms,
        offline_1t_ms,
        snap.max_rounds(Phase::Online),
    );
    coord.shutdown();

    let mut t = Table::new(&["system", "threads", "latency ms", "vs ours #4"]);
    let ours_4 = e2e_1t_ms / thread_scale(4);
    for (name, ms) in [
        ("CrypTen (GPU, published)", 21551.0),
        ("Sigma (#4, published)", sigma::LATENCY_CPU4_MS),
        ("Sigma (GPU, published)", sigma::LATENCY_GPU_MS),
    ] {
        t.row(vec![
            name.into(),
            "-".into(),
            format!("{ms:.0}"),
            format!("{:.1}x", ms / ours_4),
        ]);
    }
    for threads in [4usize, 20, 96] {
        let ms = e2e_1t_ms / thread_scale(threads);
        t.row(vec![
            "Ours (measured+scaled)".into(),
            threads.to_string(),
            format!("{ms:.0}"),
            format!("{:.1}x", ms / ours_4),
        ]);
    }
    t.print("Table 2: end-to-end BERT-base latency, LAN (paper: ours 1315/1165/969 ms; speedups 9.4x vs Sigma#4, 22x vs CrypTen)");
    println!(
        "\nshape check: ours(#4) beats Sigma(#4) by {:.1}x (paper: 9.4x) and CrypTen by {:.1}x (paper: 22x)",
        sigma::LATENCY_CPU4_MS / ours_4,
        21551.0 / ours_4
    );
}
