//! Table 3 reproduction: WAN latency (ms) vs Lu et al. (NDSS'25) for
//! sequence lengths 8/16/32.
//!
//! Paper row (WAN, ours 96 threads): seq 8: 8135.61 -> 1037.55 (x7.84),
//! seq 16: 12143.00 -> 1485.85 (x8.17), seq 32: 16764.15 -> 2143.16 (x7.82).
//!
//! Ours: measured comm/rounds/compute on a reduced-depth BERT-base run,
//! scaled to 12 layers, under the WAN model (rounds x 40 ms + bytes /
//! 100 Mbps + thread-scaled compute).
//!
//! Lu et al.: first-principles model from the paper's own accounting —
//! "256 bits of communication per multiplication gate" offline plus two
//! 8-bit openings online, applied to the model's exact MAC inventory;
//! nonlinear layers cost the same as ours (both systems share them), and
//! compute is our measured figure times the table-build overhead measured
//! on the real `lu_fc` implementation (rust/src/baselines/lu_ndss.rs).
//!
//!   cargo bench --bench table3

use ppq_bert::bench_harness::{prepared_model, thread_scale, Table};
use ppq_bert::coordinator::{Coordinator, ServerConfig};
use ppq_bert::model::config::BertConfig;
use ppq_bert::transport::{NetParams, Phase};

fn macs_per_layer(cfg: &BertConfig) -> f64 {
    let (s, d, f, h, dh) = (
        cfg.seq_len as f64,
        cfg.d_model as f64,
        cfg.d_ff as f64,
        cfg.n_heads as f64,
        cfg.d_head() as f64,
    );
    // QKV + O projections, FFN up/down, QK^T and attn.V per head
    s * d * d * 4.0 + 2.0 * s * d * f + h * (s * s * dh * 2.0)
}

fn main() {
    let mut t = Table::new(&["seq", "Lu et al. s", "ours #20 s", "ours #96 s", "speedup(96)"]);
    let measured_layers = 2usize;
    let layer_scale = 12.0 / measured_layers as f64;
    let wan = NetParams::WAN;

    for seq in [8usize, 16, 32] {
        let cfg = BertConfig::base_with_seq(seq).with_layers(measured_layers);
        let (w, x) = prepared_model(cfg);
        let mut sc = ServerConfig::new(cfg);
        sc.net = wan;
        let mut coord = Coordinator::start(sc, w);
        coord.submit(x);
        let _ = coord.run_batch();
        let snap = coord.snapshot();
        coord.shutdown();

        // ours under WAN, scaled to 12 layers
        let bytes = (snap.busiest_link_bytes(Phase::Online)
            + snap.busiest_link_bytes(Phase::Offline)) as f64
            * layer_scale;
        let rounds = (snap.max_rounds(Phase::Online) + snap.max_rounds(Phase::Offline)) as f64
            * layer_scale;
        let comp = (snap.max_compute_ns(Phase::Online) + snap.max_compute_ns(Phase::Offline))
            as f64
            / 1e9
            * layer_scale;
        let ours = |threads: usize| {
            comp / thread_scale(threads) + rounds * wan.rtt.as_secs_f64() + bytes * 8.0 / wan.bandwidth_bps
        };

        // Lu et al.: replace the linear layers' comm with per-gate LUT cost.
        let full = BertConfig::base_with_seq(seq);
        let macs = macs_per_layer(&full) * 12.0;
        let lu_off_bytes = macs * 32.0; // 256 bits/gate (paper, Introduction)
        let lu_on_bytes = macs * 2.0; // two 8-bit openings per gate
        let lu_compute = comp * 4.0; // measured lu_fc table-build overhead
        let lu_s = lu_compute / thread_scale(96)
            + rounds * wan.rtt.as_secs_f64()
            + (bytes + lu_off_bytes + lu_on_bytes) * 8.0 / wan.bandwidth_bps;

        let (o20, o96) = (ours(20), ours(96));
        t.row(vec![
            seq.to_string(),
            format!("{lu_s:.0}"),
            format!("{o20:.0}"),
            format!("{o96:.0}"),
            format!("x{:.2}", lu_s / o96),
        ]);
    }
    t.print("Table 3: WAN latency vs Lu et al. (paper: 8136->1038s x7.84 / 12143->1486 x8.17 / 16764->2143 x7.82)");
}
