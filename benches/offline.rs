//! Warm vs cold correlation pool: the offline/online split as a measured
//! architectural property (DESIGN.md §Offline preprocessing), plus the
//! per-op offline cost breakdown derived from the secure op graph
//! (DESIGN.md §Secure op graph).
//!
//! For each batch size B the coordinator serves one window of B requests
//! twice: once with an empty pool (cold — every lookup generates its
//! masked table inline, so the offline phase sits on the request path)
//! and once with the window's correlation tape generated ahead of time
//! (warm — the request path carries only δ openings). The table prints
//! the request-path round/byte split per phase and the modeled LAN/WAN
//! request-path latency; online traffic is identical in both rows by
//! construction (pooling never touches `Phase::Online`), which
//! `rust/tests/prep_tests.rs` asserts along with bit-for-bit logits
//! parity.
//!
//! The second table walks the graph's offline plan (share-less dry
//! build — no session) and prints each node's correlation count and
//! modeled P0→P2 bytes; `rust/tests/graph_tests.rs` pins these modeled
//! bytes equal to the metered cold-window traffic.
//!
//!   cargo bench --bench offline
//!   CI smoke: cargo bench --bench offline -- --quick --json BENCH_ci.json

use std::sync::Arc;
use std::time::{Duration, Instant};

use ppq_bert::bench_harness::{fmt_dur, prepared_inputs, prepared_model, BenchOpts, Table};
use ppq_bert::coordinator::session::{prep_into_pool, serve_window};
use ppq_bert::coordinator::{Coordinator, ServerConfig};
use ppq_bert::model::config::{BertConfig, TaskKind};
use ppq_bert::model::passes::OptConfig;
use ppq_bert::model::secure::GraphSpec;
use ppq_bert::party::{PartyCtx, SessionCfg, P0, P1};
use ppq_bert::protocols::prep::{dedup_groups, field_count};
use ppq_bert::protocols::tape_store::{TapePool, TapeStore};
use ppq_bert::transport::{build_mesh, Metrics, MetricsSnapshot, NetParams, Phase};

fn main() {
    let opts = BenchOpts::from_env_args();
    let cfg = BertConfig::tiny();
    let batches: &[usize] = if opts.quick { &[1] } else { &[1, 4] };
    let mut t = Table::new(&[
        "batch",
        "pool",
        "req-path offline rounds",
        "req-path offline MB",
        "online rounds",
        "online MB",
        "LAN req-path",
        "WAN req-path",
    ]);

    for &batch in batches {
        for warm in [false, true] {
            // Fresh coordinator per point so the per-window delta in the
            // InferenceResult is exactly this window's request path.
            let (w, _) = prepared_model(cfg);
            let mut sc = ServerConfig::new(cfg);
            sc.max_batch = batch;
            sc.prep_depth = usize::from(warm);
            let mut coord = Coordinator::start(sc, w);
            let pre = coord.snapshot();
            for x in prepared_inputs(&cfg, batch) {
                coord.submit(x);
            }
            let results = coord.run_batch();
            assert_eq!(results.len(), batch);
            let r0 = &results[0];
            assert_eq!(r0.window_pool_misses > 0, !warm, "pool state must match the sweep point");

            // Request-path delta of the one served window.
            let mut delta = coord.snapshot();
            delta.saturating_sub_assign(&pre);
            // run_batch tops the pool back up afterwards; subtract that
            // by using the per-result amortized fields for bytes and the
            // window fields for rounds.
            let window_offline_bytes: u64 = results.iter().map(|r| r.offline_bytes).sum();
            let req_path = |net: NetParams, d: &MetricsSnapshot| {
                if warm {
                    // warm: offline delta in `d` is refill traffic, not
                    // request path — the request path is online only
                    net.modeled_net_time(d, Phase::Online)
                } else {
                    net.modeled_net_time(d, Phase::Offline) + net.modeled_net_time(d, Phase::Online)
                }
            };

            t.row(vec![
                batch.to_string(),
                if warm { "warm" } else { "cold" }.to_string(),
                if warm { 0 } else { delta.max_rounds(Phase::Offline) }.to_string(),
                format!("{:.2}", window_offline_bytes as f64 / 1048576.0),
                r0.window_online_rounds.to_string(),
                format!("{:.2}", delta.total_bytes(Phase::Online) as f64 / 1048576.0),
                fmt_dur(req_path(NetParams::LAN, &delta)),
                fmt_dur(req_path(NetParams::WAN, &delta)),
            ]);
            opts.record(
                &format!("offline/b{batch}/{}", if warm { "warm" } else { "cold" }),
                r0.compute,
                window_offline_bytes,
                r0.window_online_rounds,
            );
            coord.shutdown();
        }
    }
    t.print(
        "offline/online split: a warm correlation pool moves ALL offline traffic off the \
         request path (online rounds/bytes identical warm vs cold; BERT-tiny, window = batch)",
    );

    // Per-op offline cost from the graph walk: what each node of the
    // secure op graph will consume for one window, as modeled P0→P2
    // correction bytes (no session needed — the dry build carries no
    // shares but all shapes).
    let plan_batch = if opts.quick { 1 } else { 4 };
    let g = GraphSpec::new(TaskKind::Classify, cfg).dry();
    let mut per_node: Vec<(String, usize, u64)> = Vec::new();
    for e in g.plan_entries(plan_batch) {
        let merged = match per_node.last_mut() {
            Some(last) if last.0 == e.node => {
                last.1 += 1;
                last.2 += e.bytes;
                true
            }
            _ => false,
        };
        if !merged {
            per_node.push((e.node.clone(), 1, e.bytes));
        }
    }
    let mut t2 = Table::new(&["node", "correlations", "offline KiB"]);
    let mut total = 0u64;
    for (node, count, bytes) in &per_node {
        total += bytes;
        t2.row(vec![
            node.clone(),
            count.to_string(),
            format!("{:.1}", *bytes as f64 / 1024.0),
        ]);
        opts.record(&format!("offline/plan/b{plan_batch}/{node}"), Duration::ZERO, *bytes, 0);
    }
    t2.print(&format!(
        "per-op offline tape of `{}` (graph walk, B = {plan_batch} window): {:.2} MiB total — \
         also dumpable via `repro plan --json`",
        g.name(),
        total as f64 / 1048576.0,
    ));

    // Restart-to-first-warm-window: the durability path measured end to
    // end (DESIGN.md §Durability & recovery). Three parties prep one
    // window's correlation tape, persist their pools through
    // `TapeStore`, and the deployment is discarded. The timed region is
    // everything a restarted deployment does before its first logits:
    // open the stores, stream the tapes back (CRC-checked), rebuild the
    // model setup, and serve one window — which must consume the
    // reloaded tape, i.e. carry zero request-path offline bytes.
    let session_label = *b"bench-recovery-0";
    let scfg = SessionCfg::default();
    let (weights, input) = prepared_model(cfg);
    let weights = Arc::new(weights);
    let dirs: Vec<std::path::PathBuf> = (0..3)
        .map(|id| std::env::temp_dir().join(format!("ppq_bench_recovery_p{id}")))
        .collect();
    for d in &dirs {
        let _ = std::fs::remove_dir_all(d);
    }

    // Seed the stores: one warm tape per party, persisted, then dropped
    // (as a crash would drop it).
    let nets = build_mesh(Arc::new(Metrics::new()), None);
    let mut seed = Vec::new();
    for (id, net) in nets.into_iter().enumerate() {
        let weights = Arc::clone(&weights);
        let dir = dirs[id].clone();
        seed.push(std::thread::spawn(move || {
            let ctx = PartyCtx::new(id, net, scfg.master_seed, scfg.threads);
            let w = if id == P0 { Some(&*weights) } else { None };
            let model = GraphSpec::new(TaskKind::Classify, cfg).build(&ctx, w);
            let mut pool = TapePool::new();
            prep_into_pool(&ctx, &model, &mut pool, 1);
            let store = TapeStore::new(dir, id, session_label).expect("open tape store");
            store.save_pool(&pool).expect("persist pool");
            ctx.flush_timer();
        }));
    }
    for h in seed {
        h.join().expect("seed party");
    }

    let restart_metrics = Arc::new(Metrics::new());
    let nets = build_mesh(Arc::clone(&restart_metrics), None);
    let (logits_tx, logits_rx) = std::sync::mpsc::channel();
    let start = Instant::now();
    let mut restarted = Vec::new();
    for (id, net) in nets.into_iter().enumerate() {
        let weights = Arc::clone(&weights);
        let dir = dirs[id].clone();
        let input = input.clone();
        let logits_tx = logits_tx.clone();
        restarted.push(std::thread::spawn(move || {
            let store = TapeStore::new(dir, id, session_label).expect("open tape store");
            let (mut pool, warnings) = store.load_pool();
            assert!(warnings.is_empty(), "tape reload warnings: {warnings:?}");
            let ctx = PartyCtx::new(id, net, scfg.master_seed, scfg.threads);
            let w = if id == P0 { Some(&*weights) } else { None };
            let model = GraphSpec::new(TaskKind::Classify, cfg).build(&ctx, w);
            let inputs = if id == P1 { Some(vec![input]) } else { None };
            let logits = serve_window(&ctx, &model, &mut pool, 1, inputs.as_deref());
            ctx.flush_timer();
            if id == P1 {
                let _ = logits_tx.send(logits);
            }
        }));
    }
    for h in restarted {
        h.join().expect("restarted party");
    }
    let wall = start.elapsed();
    let logits = logits_rx.recv().expect("warm logits after restart");
    assert!(!logits.is_empty() && logits[0].len() == cfg.n_classes);
    let d = restart_metrics.snapshot();
    let offline_bytes = d.total_bytes(Phase::Offline);
    assert_eq!(offline_bytes, 0, "the restarted window must consume the reloaded tape (warm)");
    opts.record("recovery_warm_window", wall, offline_bytes, d.max_rounds(Phase::Online));
    for dir in &dirs {
        let _ = std::fs::remove_dir_all(dir);
    }

    let mut t3 = Table::new(&["restart path", "wall", "req-path offline B", "online rounds"]);
    t3.row(vec![
        "tape reload + setup + 1 window".to_string(),
        fmt_dur(wall),
        offline_bytes.to_string(),
        d.max_rounds(Phase::Online).to_string(),
    ]);
    t3.print(
        "restart-to-first-warm-window: a party rebuilt from its durable tape store serves its \
         first window with zero request-path offline traffic (DESIGN.md §Durability & recovery)",
    );

    // Optimizer dedup: prep the same one-window tape at --opt 0 vs
    // --opt 1. Dedup batches identical-shape P0→P2 correction fields
    // into one message per shape group, so the prep (offline) round
    // count drops while bytes and the produced tape stay identical
    // (rust/tests/opt_tests.rs pins the tape field-for-field).
    let mut t4 = Table::new(&["opt", "prep offline rounds", "offline MiB", "P0->P2 msgs"]);
    let mut prep_rounds = [0u64; 2];
    for level in [0u8, 1] {
        let opt = OptConfig::from_level(level);
        let metrics = Arc::new(Metrics::new());
        let nets = build_mesh(Arc::clone(&metrics), None);
        let start = Instant::now();
        let mut parties = Vec::new();
        for (id, net) in nets.into_iter().enumerate() {
            let weights = Arc::clone(&weights);
            parties.push(std::thread::spawn(move || {
                let ctx = PartyCtx::new(id, net, scfg.master_seed, scfg.threads);
                let w = if id == P0 { Some(&*weights) } else { None };
                let model = GraphSpec::new(TaskKind::Classify, cfg).with_opt(opt).build(&ctx, w);
                let mut pool = TapePool::new();
                prep_into_pool(&ctx, &model, &mut pool, 1);
                ctx.flush_timer();
            }));
        }
        for h in parties {
            h.join().expect("prep party");
        }
        let wall = start.elapsed();
        let d = metrics.snapshot();
        let dry = GraphSpec::new(TaskKind::Classify, cfg).with_opt(opt).dry();
        let plan = dry.plan(1);
        let msgs: usize = if level == 0 {
            plan.iter().map(|op| field_count(&op.shape())).sum()
        } else {
            dedup_groups(&plan).len()
        };
        prep_rounds[level as usize] = d.max_rounds(Phase::Offline);
        opts.record(
            &format!("offline/opt_dedup/opt{level}"),
            wall,
            d.total_bytes(Phase::Offline),
            d.max_rounds(Phase::Offline),
        );
        t4.row(vec![
            format!("--opt {level}"),
            d.max_rounds(Phase::Offline).to_string(),
            format!("{:.2}", d.total_bytes(Phase::Offline) as f64 / 1048576.0),
            msgs.to_string(),
        ]);
    }
    assert!(
        prep_rounds[1] < prep_rounds[0],
        "opt1 prep must measure strictly fewer offline rounds ({} vs {})",
        prep_rounds[1],
        prep_rounds[0],
    );
    t4.print(
        "correlation dedup: --opt 1 preps one window with one P0->P2 message per shape group \
         (same bytes and tape, fewer offline rounds; DESIGN.md §Graph optimizer)",
    );
}
