//! Warm vs cold correlation pool: the offline/online split as a measured
//! architectural property (DESIGN.md §Offline preprocessing), plus the
//! per-op offline cost breakdown derived from the secure op graph
//! (DESIGN.md §Secure op graph).
//!
//! For each batch size B the coordinator serves one window of B requests
//! twice: once with an empty pool (cold — every lookup generates its
//! masked table inline, so the offline phase sits on the request path)
//! and once with the window's correlation tape generated ahead of time
//! (warm — the request path carries only δ openings). The table prints
//! the request-path round/byte split per phase and the modeled LAN/WAN
//! request-path latency; online traffic is identical in both rows by
//! construction (pooling never touches `Phase::Online`), which
//! `rust/tests/prep_tests.rs` asserts along with bit-for-bit logits
//! parity.
//!
//! The second table walks the graph's offline plan (share-less dry
//! build — no session) and prints each node's correlation count and
//! modeled P0→P2 bytes; `rust/tests/graph_tests.rs` pins these modeled
//! bytes equal to the metered cold-window traffic.
//!
//!   cargo bench --bench offline
//!   CI smoke: cargo bench --bench offline -- --quick --json BENCH_ci.json

use std::time::Duration;

use ppq_bert::bench_harness::{fmt_dur, prepared_inputs, prepared_model, BenchOpts, Table};
use ppq_bert::coordinator::{Coordinator, ServerConfig};
use ppq_bert::model::config::{BertConfig, LayerQuantConfig};
use ppq_bert::model::secure::bert_graph_dry;
use ppq_bert::protocols::max::MaxStrategy;
use ppq_bert::transport::{MetricsSnapshot, NetParams, Phase};

fn main() {
    let opts = BenchOpts::from_env_args();
    let cfg = BertConfig::tiny();
    let batches: &[usize] = if opts.quick { &[1] } else { &[1, 4] };
    let mut t = Table::new(&[
        "batch",
        "pool",
        "req-path offline rounds",
        "req-path offline MB",
        "online rounds",
        "online MB",
        "LAN req-path",
        "WAN req-path",
    ]);

    for &batch in batches {
        for warm in [false, true] {
            // Fresh coordinator per point so the per-window delta in the
            // InferenceResult is exactly this window's request path.
            let (w, _) = prepared_model(cfg);
            let mut sc = ServerConfig::new(cfg);
            sc.max_batch = batch;
            sc.prep_depth = usize::from(warm);
            let mut coord = Coordinator::start(sc, w);
            let pre = coord.snapshot();
            for x in prepared_inputs(&cfg, batch) {
                coord.submit(x);
            }
            let results = coord.run_batch();
            assert_eq!(results.len(), batch);
            let r0 = &results[0];
            assert_eq!(r0.window_pool_misses > 0, !warm, "pool state must match the sweep point");

            // Request-path delta of the one served window.
            let mut delta = coord.snapshot();
            delta.saturating_sub_assign(&pre);
            // run_batch tops the pool back up afterwards; subtract that
            // by using the per-result amortized fields for bytes and the
            // window fields for rounds.
            let window_offline_bytes: u64 = results.iter().map(|r| r.offline_bytes).sum();
            let req_path = |net: NetParams, d: &MetricsSnapshot| {
                if warm {
                    // warm: offline delta in `d` is refill traffic, not
                    // request path — the request path is online only
                    net.modeled_net_time(d, Phase::Online)
                } else {
                    net.modeled_net_time(d, Phase::Offline) + net.modeled_net_time(d, Phase::Online)
                }
            };

            t.row(vec![
                batch.to_string(),
                if warm { "warm" } else { "cold" }.to_string(),
                if warm { 0 } else { delta.max_rounds(Phase::Offline) }.to_string(),
                format!("{:.2}", window_offline_bytes as f64 / 1048576.0),
                r0.window_online_rounds.to_string(),
                format!("{:.2}", delta.total_bytes(Phase::Online) as f64 / 1048576.0),
                fmt_dur(req_path(NetParams::LAN, &delta)),
                fmt_dur(req_path(NetParams::WAN, &delta)),
            ]);
            opts.record(
                &format!("offline/b{batch}/{}", if warm { "warm" } else { "cold" }),
                r0.compute,
                window_offline_bytes,
                r0.window_online_rounds,
            );
            coord.shutdown();
        }
    }
    t.print(
        "offline/online split: a warm correlation pool moves ALL offline traffic off the \
         request path (online rounds/bytes identical warm vs cold; BERT-tiny, window = batch)",
    );

    // Per-op offline cost from the graph walk: what each node of the
    // secure op graph will consume for one window, as modeled P0→P2
    // correction bytes (no session needed — the dry build carries no
    // shares but all shapes).
    let plan_batch = if opts.quick { 1 } else { 4 };
    let g = bert_graph_dry(&cfg, &LayerQuantConfig::uniform(&cfg, MaxStrategy::Tournament));
    let mut per_node: Vec<(String, usize, u64)> = Vec::new();
    for e in g.plan_entries(plan_batch) {
        let merged = match per_node.last_mut() {
            Some(last) if last.0 == e.node => {
                last.1 += 1;
                last.2 += e.bytes;
                true
            }
            _ => false,
        };
        if !merged {
            per_node.push((e.node.clone(), 1, e.bytes));
        }
    }
    let mut t2 = Table::new(&["node", "correlations", "offline KiB"]);
    let mut total = 0u64;
    for (node, count, bytes) in &per_node {
        total += bytes;
        t2.row(vec![
            node.clone(),
            count.to_string(),
            format!("{:.1}", *bytes as f64 / 1024.0),
        ]);
        opts.record(&format!("offline/plan/b{plan_batch}/{node}"), Duration::ZERO, *bytes, 0);
    }
    t2.print(&format!(
        "per-op offline tape of `{}` (graph walk, B = {plan_batch} window): {:.2} MiB total — \
         also dumpable via `repro plan --json`",
        g.name(),
        total as f64 / 1048576.0,
    ));
}
