//! Batch-size sweep: cross-request round amortization in the serving
//! coordinator.
//!
//! For each batch size B the coordinator drains one window of B requests
//! as a single batched MPC pass. The headline invariant is that the
//! window's measured online rounds are CONSTANT in B (they equal the
//! B = 1 round count), so rounds/request — the quantity that dominates
//! WAN latency — falls as 1/B, while online bytes/request stay flat
//! (bytes scale linearly with B). The printed modeled latencies show what
//! that amortization buys per request under LAN and WAN.
//!
//!   cargo bench --bench batching
//!   cargo bench --bench batching -- --quick --json BENCH_ci.json   (CI smoke)

use ppq_bert::bench_harness::{fmt_dur, prepared_inputs, prepared_model, BenchOpts, Table};
use ppq_bert::coordinator::{Coordinator, ServerConfig};
use ppq_bert::model::config::BertConfig;
use ppq_bert::transport::{NetParams, Phase};

fn main() {
    let opts = BenchOpts::from_env_args();
    let cfg = BertConfig::tiny();
    let mut t = Table::new(&[
        "batch",
        "window rounds",
        "rounds/req",
        "online MB/req",
        "LAN window",
        "LAN /req",
        "WAN window",
        "WAN /req",
    ]);

    let sweep: &[usize] = if opts.quick { &[1, 4] } else { &[1, 2, 4, 8] };
    let mut base_rounds = None;
    for &batch in sweep {
        // Fresh coordinator per sweep point so the session meter starts
        // clean; with exactly one window served, the cumulative Online
        // meter IS the window's delta.
        let (w, _) = prepared_model(cfg);
        let mut sc = ServerConfig::new(cfg);
        sc.max_batch = batch;
        let mut coord = Coordinator::start(sc, w);
        for x in prepared_inputs(&cfg, batch) {
            coord.submit(x);
        }
        let results = coord.run_batch();
        assert_eq!(results.len(), batch);
        let r0 = &results[0];
        assert_eq!(r0.batch_size, batch);

        let rounds = r0.window_online_rounds;
        match base_rounds {
            None => base_rounds = Some(rounds),
            Some(b1) => assert_eq!(
                rounds, b1,
                "online rounds must be constant in batch size (B=1: {b1}, B={batch}: {rounds})"
            ),
        }

        let online_mb_req: f64 = results
            .iter()
            .map(|r| r.online_bytes as f64 / 1048576.0)
            .sum::<f64>()
            / batch as f64;
        let snap = coord.snapshot();
        let lan_window = NetParams::LAN.modeled_phase_time(&snap, Phase::Online);
        let wan_window = NetParams::WAN.modeled_phase_time(&snap, Phase::Online);
        opts.record(
            &format!("batching/window_b{batch}"),
            r0.compute,
            snap.total_bytes(Phase::Online),
            rounds,
        );
        t.row(vec![
            batch.to_string(),
            rounds.to_string(),
            format!("{:.1}", rounds as f64 / batch as f64),
            format!("{online_mb_req:.3}"),
            fmt_dur(lan_window),
            fmt_dur(lan_window / batch as u32),
            fmt_dur(wan_window),
            fmt_dur(wan_window / batch as u32),
        ]);
        coord.shutdown();
    }
    t.print(
        "cross-request batching: online rounds/window constant in B -> rounds/request fall 1/B \
         (BERT-tiny; WAN = 40 ms RTT, where round amortization dominates)",
    );
}
