//! Figure 5 reproduction: single-token-output latency across network
//! settings (LAN/WAN), thread counts (1/4/20) and sequence lengths
//! (8..128), split into offline + online phases.
//!
//! Method: measured reduced-depth runs (comm metered exactly, compute
//! measured) scaled to 12 layers; thread scaling via the calibrated
//! Amdahl curve; network time from the rounds/bytes model (DESIGN.md).
//!
//!   cargo bench --bench fig5

use ppq_bert::bench_harness::{prepared_model, thread_scale, Table};
use ppq_bert::coordinator::{Coordinator, ServerConfig};
use ppq_bert::model::config::BertConfig;
use ppq_bert::transport::{NetParams, Phase};

fn main() {
    let measured_layers = 2usize;
    let layer_scale = 12.0 / measured_layers as f64;
    let seqs = [8usize, 16, 32, 64, 128];
    let threads = [1usize, 4, 20];

    for net in [NetParams::LAN, NetParams::WAN] {
        let mut t = Table::new(&[
            "seq", "threads", "offline s", "online s", "total s",
        ]);
        for &seq in &seqs {
            let cfg = BertConfig::base_with_seq(seq).with_layers(measured_layers);
            let (w, x) = prepared_model(cfg);
            let mut sc = ServerConfig::new(cfg);
            sc.net = net;
            let mut coord = Coordinator::start(sc, w);
            coord.submit(x);
            let r = coord.run_batch().remove(0);
            let snap = coord.snapshot();
            coord.shutdown();

            // split: phase compute (measured) + phase network (modeled)
            let comp_off = snap.max_compute_ns(Phase::Offline) as f64 / 1e9 * layer_scale;
            let comp_on = snap.max_compute_ns(Phase::Online) as f64 / 1e9 * layer_scale;
            let net_off = (net.modeled_net_time(&snap, Phase::Offline)).as_secs_f64() * layer_scale;
            let net_on = (net.modeled_net_time(&snap, Phase::Online)).as_secs_f64() * layer_scale;
            let _ = r;
            for &th in &threads {
                let off = comp_off / thread_scale(th) + net_off;
                let on = comp_on / thread_scale(th) + net_on;
                t.row(vec![
                    seq.to_string(),
                    th.to_string(),
                    format!("{off:.2}"),
                    format!("{on:.2}"),
                    format!("{:.2}", off + on),
                ]);
            }
        }
        t.print(&format!(
            "Fig. 5 ({}): latency per inference, offline+online (paper: ~1s online @ seq 8 / 20 threads LAN; <4s @ 128)",
            net.name
        ));
    }
}
