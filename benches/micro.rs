//! Protocol micro-benchmarks + ablations (harness = false; criterion is
//! unavailable offline — timings are median-of-N via bench_harness).
//!
//!   cargo bench --bench micro

use ppq_bert::bench_harness::{fmt_dur, time_median, Table};
use ppq_bert::core::ring::{R16, R4};
use ppq_bert::party::{run_3pc, SessionCfg, P0, P1};
use ppq_bert::protocols::convert::convert_to_rss;
use ppq_bert::protocols::lut::{lut_eval, LutTable};
use ppq_bert::protocols::matmul::rss_matmul_trc;
use ppq_bert::protocols::max::{max_rows, MaxStrategy};
use ppq_bert::protocols::softmax::{softmax_rows, SoftmaxTables};
use ppq_bert::sharing::additive::share2;
use ppq_bert::sharing::rss::share_rss;
use ppq_bert::transport::Phase;

fn main() {
    let mut t = Table::new(&["op", "shape", "median", "online B", "offline B", "rounds"]);

    // LUT evaluation throughput
    for n in [256usize, 4096] {
        let mut snap_keep = None;
        let d = time_median(5, || {
            let (_, snap) = run_3pc(SessionCfg::default(), move |ctx| {
                let table = LutTable::from_fn(R4, R16, |v| v * 3);
                let xs: Option<Vec<u64>> =
                    if ctx.id == P0 { Some((0..n as u64).map(|i| i % 16).collect()) } else { None };
                let x = ctx.with_phase(Phase::Setup, |c| share2(c, P0, R4, xs.as_deref(), n));
                lut_eval(ctx, &table, &x);
            });
            snap_keep = Some(snap);
        });
        let s = snap_keep.unwrap();
        t.row(vec![
            "Pi_look 4->16".into(),
            format!("{n}"),
            fmt_dur(d),
            s.total_bytes(Phase::Online).to_string(),
            s.total_bytes(Phase::Offline).to_string(),
            s.max_rounds(Phase::Online).to_string(),
        ]);
    }

    // share conversion
    for n in [1024usize] {
        let mut snap_keep = None;
        let d = time_median(5, || {
            let (_, snap) = run_3pc(SessionCfg::default(), move |ctx| {
                let xs: Option<Vec<u64>> =
                    if ctx.id == P0 { Some((0..n as u64).map(|i| i % 16).collect()) } else { None };
                let x = ctx.with_phase(Phase::Setup, |c| share2(c, P0, R4, xs.as_deref(), n));
                convert_to_rss(ctx, &x, R16, true);
            });
            snap_keep = Some(snap);
        });
        let s = snap_keep.unwrap();
        t.row(vec![
            "Pi_convert 4->16".into(),
            format!("{n}"),
            fmt_dur(d),
            s.total_bytes(Phase::Online).to_string(),
            s.total_bytes(Phase::Offline).to_string(),
            s.max_rounds(Phase::Online).to_string(),
        ]);
    }

    // RSS FC (Alg. 3) at BERT-base shape
    for (rows, k, m) in [(8usize, 768usize, 768usize)] {
        let mut snap_keep = None;
        let d = time_median(3, || {
            let (_, snap) = run_3pc(SessionCfg::default(), move |ctx| {
                let xs: Option<Vec<u64>> =
                    if ctx.id == P1 { Some(vec![3u64; rows * k]) } else { None };
                let ws: Option<Vec<u64>> =
                    if ctx.id == P0 { Some(vec![64u64; m * k]) } else { None };
                let x = ctx.with_phase(Phase::Setup, |c| share_rss(c, P1, R16, xs.as_deref(), rows * k));
                let w = ctx.with_phase(Phase::Setup, |c| share_rss(c, P0, R16, ws.as_deref(), m * k));
                rss_matmul_trc(ctx, &x, &w, rows, k, m, 4);
            });
            snap_keep = Some(snap);
        });
        let s = snap_keep.unwrap();
        t.row(vec![
            "Alg3 FC".into(),
            format!("{rows}x{k}->{m}"),
            fmt_dur(d),
            s.total_bytes(Phase::Online).to_string(),
            s.total_bytes(Phase::Offline).to_string(),
            s.max_rounds(Phase::Online).to_string(),
        ]);
    }

    // softmax rows at attention shape
    for (rows, n) in [(8usize, 8usize), (32, 32)] {
        let mut snap_keep = None;
        let d = time_median(3, || {
            let (_, snap) = run_3pc(SessionCfg::default(), move |ctx| {
                let tables = SoftmaxTables::new(0.5);
                let xs: Option<Vec<u64>> =
                    if ctx.id == P0 { Some((0..(rows * n) as u64).map(|i| i % 16).collect()) } else { None };
                let x = ctx.with_phase(Phase::Setup, |c| share2(c, P0, R4, xs.as_deref(), rows * n));
                softmax_rows(ctx, &tables, &x, rows, n, MaxStrategy::Tournament);
            });
            snap_keep = Some(snap);
        });
        let s = snap_keep.unwrap();
        t.row(vec![
            "softmax".into(),
            format!("{rows}x{n}"),
            fmt_dur(d),
            s.total_bytes(Phase::Online).to_string(),
            s.total_bytes(Phase::Offline).to_string(),
            s.max_rounds(Phase::Online).to_string(),
        ]);
    }

    // ablation: Pi_max tournament vs linear (rounds under WAN)
    for strat in [MaxStrategy::Tournament, MaxStrategy::Linear, MaxStrategy::Sort] {
        let (rows, n) = (8usize, 32usize);
        let mut snap_keep = None;
        let d = time_median(3, || {
            let (_, snap) = run_3pc(SessionCfg::default(), move |ctx| {
                let xs: Option<Vec<u64>> =
                    if ctx.id == P0 { Some((0..(rows * n) as u64).map(|i| i % 16).collect()) } else { None };
                let x = ctx.with_phase(Phase::Setup, |c| share2(c, P0, R4, xs.as_deref(), rows * n));
                max_rows(ctx, &x, rows, n, strat);
            });
            snap_keep = Some(snap);
        });
        let s = snap_keep.unwrap();
        let wan_online =
            ppq_bert::transport::NetParams::WAN.modeled_phase_time(&s, Phase::Online);
        t.row(vec![
            format!("Pi_max {strat:?}"),
            format!("{rows}x{n}"),
            fmt_dur(d),
            s.total_bytes(Phase::Online).to_string(),
            format!("WAN {}", fmt_dur(wan_online)),
            s.max_rounds(Phase::Online).to_string(),
        ]);
    }

    t.print("protocol microbenchmarks (per 3-party session)");
}
