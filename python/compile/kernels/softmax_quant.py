"""L1 Pallas kernel: quantized softmax (paper, "Softmax" + Fig. 4).

Implements the exact integer pipeline of the MPC protocol (max -> exp LUT
-> 8-bit-ring sum -> mid-4-bit denominator -> two-input division LUT) as a
Pallas kernel so it lowers into the same HLO module as the matmul kernels.

The two 16/256-entry tables are baked into the kernel as constants — on a
real TPU they are VMEM-resident for the whole kernel (DESIGN.md
§Hardware-Adaptation); lookups are VPU gathers.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

MASK4 = 0xF
MASK8 = 0xFF


def _softmax_kernel(x_ref, te_ref, td_ref, o_ref):
    """Rows of quantized softmax. x [BM, N] signed-4b int32."""
    x = x_ref[...]
    te = te_ref[...]
    td = td_ref[...]
    xo = jnp.max(x, axis=-1, keepdims=True)
    d = (x - xo) & MASK4
    e = ref.table_lookup(te, d)
    big = jnp.sum(e, axis=-1, keepdims=True) & MASK8
    num = e & MASK4
    den = (big >> 4) & MASK4
    o_ref[...] = ref.table_lookup(td, num * 16 + den)


def softmax_quant_pallas(x4, sx, block_m=None):
    """Pallas quantized softmax over the last axis of x4 [M, N]."""
    m, n = x4.shape
    bm = block_m or min(m, 128)
    assert m % bm == 0
    te = ref.exp_table(sx).astype(jnp.int32)
    td = ref.div_table().astype(jnp.int32)
    return pl.pallas_call(
        _softmax_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((16,), lambda i: (0,)),
            pl.BlockSpec((256,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(x4, te, td)
