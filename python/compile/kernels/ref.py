"""Pure-jnp integer reference semantics — THE specification.

Every other implementation (the Pallas kernels, the L2 jax model, the Rust
native plaintext model in ``rust/src/runtime/native.rs``, and the Rust MPC
protocols in ``rust/src/protocols/``) must agree with these functions
bit-exactly (MPC is allowed +/-1 LSB at local-truncation points, see
DESIGN.md).

Quantization scheme (paper, "Our BERT Model Structure"):
  * weights   : 1 bit,  W in {-1, +1}, with a per-layer integer scale
                ``scale = floor(2^12 * s_w * s_x / s_y)``
  * activations: 4 bit, signed in [-8, 7] or unsigned in [0, 15]
  * linear layers run over the 16-bit ring Z_2^16; the rescale to 4 bits is
    ``trc(acc, 4)`` = keep the top 4 bits (acc >> 12), which is exact
    because the scale shifts the quantized output into the top nibble
    (paper, Alg. 3)
  * softmax runs over the 8-bit ring with a 4-bit exp LUT and a two-input
    4x4-bit division LUT (paper, Fig. 4)

All tensors are int32; ring arithmetic is emulated with masks.
"""

import numpy as np
import jax.numpy as jnp

MASK4 = 0xF
MASK8 = 0xFF
MASK16 = 0xFFFF


def table_lookup(table, idx):
    """Gather-free table lookup: one-hot(idx) @ table.

    The AOT interchange (HLO text through xla_extension 0.5.1) mis-parses
    jax's ``gather`` encoding — the executable returns the *indices* — so
    every table lookup on the artifact path is expressed as a one-hot
    matmul instead. This is also the TPU-friendly formulation (MXU work,
    no dynamic addressing; see DESIGN.md §Hardware-Adaptation).
    """
    n = table.shape[0]
    onehot = (idx[..., None] == jnp.arange(n, dtype=jnp.int32)).astype(jnp.int32)
    return onehot @ table


def signed4(v):
    """Interpret the low 4 bits of ``v`` as a signed 4-bit value in [-8, 7]."""
    return ((v & MASK4) ^ 0x8) - 0x8


def signed_width(v, bits):
    """Interpret the low ``bits`` bits of ``v`` as signed two's complement."""
    mask = (1 << bits) - 1
    sign = 1 << (bits - 1)
    return ((v & mask) ^ sign) - sign


def trc16_to4(acc16):
    """Paper's trc(x, 4) on the 16-bit ring: keep the top nibble, signed."""
    return signed4((acc16 & MASK16) >> 12)


# ---------------------------------------------------------------------------
# Linear layers (paper Alg. 3 / Eq. 1-2)
# ---------------------------------------------------------------------------

def fc_quant(x4, w_sign, scale):
    """Binary-weight fully connected layer.

    x4     : int32 [.., n] signed 4-bit activations in [-8, 7]
    w_sign : int32 [m, n]  binary weights in {-1, +1}
    scale  : int           floor(2^12 * s_w * s_x / s_y), |scale| < 2^15

    Returns int32 [.., m] signed 4-bit outputs.

    Semantics: acc = sum_i (scale * W_i) * x_i over Z_2^16; out = trc(acc,4).
    Products are up to 2^15*8 = 2^18 and we sum at most 3072 of them, which
    stays inside int32 when |scale| <= 2^12 (the model configs guarantee
    much smaller scales), so a single int32 dot is exact before the mod.
    """
    wq = (w_sign * scale).astype(jnp.int32)
    acc = jnp.matmul(x4.astype(jnp.int32), wq.T)
    return trc16_to4(acc)


def matmul_quant(a4, b4, scale):
    """Activation x activation quantized matmul (e.g. Q @ K^T).

    a4 [.., m, k], b4 [.., k, n] signed 4-bit; result signed 4-bit.
    acc = scale * (a @ b) over Z_2^16, out = trc(acc, 4).
    """
    acc = jnp.matmul(a4.astype(jnp.int32), b4.astype(jnp.int32)) * scale
    return trc16_to4(acc)


# ---------------------------------------------------------------------------
# Quantized softmax (paper, "Softmax" + Fig. 4)
# ---------------------------------------------------------------------------

def exp_table(sx):
    """T_exp[d mod 16] = round(15 * exp(sx * d)) for d in [-15, 0].

    Index is (d mod 16): d=0 -> 0, d=-1 -> 15, ..., d=-15 -> 1.
    Output is a 4-bit value in [0, 15] stored in an 8-bit ring.
    """
    t = np.zeros(16, dtype=np.int32)
    for d in range(-15, 1):
        t[d % 16] = int(round(15.0 * np.exp(sx * d)))
    return jnp.asarray(t)


def div_table():
    """T_div[num || den] = clip(round(16*num / (16*den + 8)), 0, 15).

    ``num`` is the 4-bit numerator e_i, ``den`` is the middle-4-bits of the
    8-bit denominator D (i.e. D >> 4). den==0 means D in [15,16) (at least
    one exp entry equals 15), handled as round(16*num/15).
    """
    t = np.zeros(256, dtype=np.int32)
    for num in range(16):
        for den in range(16):
            d_est = 16 * den + 8 if den > 0 else 15
            t[num * 16 + den] = int(np.clip(round(16.0 * num / d_est), 0, 15))
    return jnp.asarray(t)


def softmax_quant(x4, sx):
    """Quantized softmax over the last axis.

    x4 : int32 [.., n] signed 4-bit scores.
    Returns int32 [.., n] unsigned 4-bit attention weights in [0, 15].

    Pipeline (identical to the MPC protocol):
      xo  = max(x)                          (Pi_max)
      d   = (x - xo) mod 16                 (local)
      e   = T_exp[d]                        (Pi_look, 4->8 bit)
      D   = sum(e) mod 256                  (local, 8-bit ring)
      num = e & 0xF                         (local: low bits of add. shares)
      den = mid4(D) = (D >> 4) & 0xF        (Pi_look, 8->4 bit)
      out = T_div[num || den]               (Pi_look^{4,4}, two-input)
    """
    te = exp_table(sx)
    td = div_table()
    xo = jnp.max(x4, axis=-1, keepdims=True)
    d = (x4 - xo) & MASK4
    e = table_lookup(te, d)
    big = jnp.sum(e, axis=-1, keepdims=True) & MASK8
    num = e & MASK4
    den = (big >> 4) & MASK4
    return table_lookup(td, num * 16 + den)


# ---------------------------------------------------------------------------
# ReLU / LayerNorm (paper, "ReLU" / "LayerNorm")
# ---------------------------------------------------------------------------

def relu_quant(x4):
    """ReLU on signed 4-bit values (a 16-entry LUT in the MPC protocol)."""
    return jnp.maximum(x4, 0)


def ln_mean(x16, n):
    """Paper's homomorphic quantized mean: floor(2^12/n)*sum -> top nibble."""
    s = jnp.sum(x16.astype(jnp.int32), axis=-1, keepdims=True)
    m16 = (s * (4096 // n)) & MASK16
    return signed4(m16 >> 12)


def ln_div_table(s_v, eps):
    """T_ln[a6 || v4] = clip(round(a / sqrt(v*s_v + eps)), -8, 7) mod 16.

    ``a6`` is (x - mu) mod 64 (signed 6-bit, bijective for [-32,31]);
    ``v4`` is the 4-bit quantized variance. Output signed 4-bit (mod-16).
    This is the paper's "lookup table with two 4-bit inputs" generalized to
    a (6,4)-bit split — our Pi_look^{b1,b2} supports arbitrary splits.
    """
    t = np.zeros(64 * 16, dtype=np.int32)
    for a6 in range(64):
        a = (a6 ^ 0x20) - 0x20  # signed 6-bit
        for v4 in range(16):
            denom = np.sqrt(v4 * s_v + eps)
            u = int(np.clip(round(a / denom), -8, 7))
            t[a6 * 16 + v4] = u & MASK4
    return jnp.asarray(t)


def layernorm_quant(x16, n, s_v, eps, gamma_sign, gamma_scale, beta4):
    """Quantized LayerNorm over the last axis of x16 (values in ~[-32,31]).

    x16        : int32 [.., n] small signed values held in the 16-bit ring
    gamma_sign : int32 [n] in {-1,+1}   (binarized LN weight)
    gamma_scale: int                     (floor(2^12 * s_g * s_u / s_out))
    beta4      : int32 [n] signed 4-bit  (quantized LN bias)

    Returns signed 4-bit output.
    """
    mu = ln_mean(x16, n)
    diff = x16 - mu
    a = diff & 0x3F  # signed 6-bit residual index
    # variance: sum (x-mu)^2, rescale by floor(2^12/n), keep the top nibble.
    var = jnp.sum(diff * diff, axis=-1, keepdims=True)
    v16 = (var * (4096 // n)) & MASK16
    v4 = (v16 >> 12) & MASK4  # unsigned 4-bit quantized variance
    tln = ln_div_table(s_v, eps)
    u4 = signed4(table_lookup(tln, a * 16 + v4))
    # gamma/beta: elementwise binary-weight multiply + rescale + add (4-bit).
    acc = (u4 * gamma_sign * gamma_scale) & MASK16
    g = trc16_to4(acc)
    return signed4((g + beta4) & MASK4)
