"""L1 Pallas kernel: binary-weight (+/-1) x int4 quantized matmul.

This is the compute hot-spot of the quantized BERT model: every FC layer is
``trc16_to4( (scale*W) @ x  mod 2^16 )`` with W in {-1,+1} and x a signed
4-bit activation (paper Alg. 3).

TPU mapping (DESIGN.md §Hardware-Adaptation): W is +/-1 so the MXU-friendly
form is ``W@x = 2*(B@x) - sum(x)`` with B in {0,1}; here we keep the direct
int32 dot and tile (BM, K) x (K, BN) blocks into VMEM via BlockSpec. The
kernel MUST be lowered with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MASK16 = 0xFFFF


def _fc_kernel(x_ref, w_ref, o_ref, *, scale):
    """One (BM, BN) output tile: acc = x_tile @ (scale*w_tile)^T, trc to 4b."""
    x = x_ref[...].astype(jnp.int32)          # [BM, K]
    w = w_ref[...].astype(jnp.int32)          # [BN, K]
    acc = jax.lax.dot_general(
        x, w * scale,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    acc = acc & MASK16
    top = acc >> 12
    o_ref[...] = ((top & 0xF) ^ 0x8) - 0x8    # signed4


def fc_quant_pallas(x4, w_sign, scale, block_m=None, block_n=None):
    """Pallas binary-FC. x4 [M, K] int32 signed-4b, w_sign [N, K] {-1,+1}.

    Grid tiles the output [M, N]; the full K dimension is kept resident in
    VMEM per tile (K <= 3072 -> x tile 128x3072x4B = 1.5 MB, w tile same;
    fits VMEM with double buffering).
    """
    m, k = x4.shape
    n, k2 = w_sign.shape
    assert k == k2, (x4.shape, w_sign.shape)
    bm = block_m or min(m, 128)
    bn = block_n or min(n, 128)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_fc_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,  # CPU-PJRT can only run interpreted Pallas
    )(x4, w_sign)


def _mm_kernel(a_ref, b_ref, o_ref, *, scale):
    """Activation x activation tile: acc = scale * (a @ b) over Z_2^16."""
    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    acc = jax.lax.dot_general(
        a, b,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ) * scale
    acc = acc & MASK16
    top = acc >> 12
    o_ref[...] = ((top & 0xF) ^ 0x8) - 0x8


def matmul_quant_pallas(a4, b4, scale):
    """Pallas activation-activation quantized matmul: [M,K] @ [K,N] -> 4b."""
    m, k = a4.shape
    k2, n = b4.shape
    assert k == k2
    return pl.pallas_call(
        functools.partial(_mm_kernel, scale=scale),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(a4, b4)
