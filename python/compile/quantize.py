"""Quantization-aware training + distillation — reproduces Fig. 1 / Table 1.

The paper trains a 1-bit-weight BERT (BiT recipe: binarize weights around
zero with a learned per-layer scale, fake-quantize activations to b bits,
then distill from a full-precision teacher) and reports accuracy vs
activation bit-width (Fig. 1) and GLUE accuracy at 1w/4a (Table 1).

GLUE and the BiT checkpoint are unreachable offline, so this module
reproduces the *trend* on synthetic GLUE-like sequence-classification
tasks with a tiny transformer trained from scratch (DESIGN.md
§Substitutions #1). The quantization scheme itself is exactly the paper's:

  W_q   = sign(W - mean(W)) * alpha_W,  alpha_W = mean(|W - mean(W)|)
  x_q   = clip(round(x / alpha_x), lo, hi) * alpha_x   (per-tensor scale,
          symmetric for signed, asymmetric for post-ReLU activations)
  straight-through estimator for both; distillation = KL(student||teacher
  logits) + MSE on hidden states.

Usage:
  python -m compile.quantize --sweep            # Fig. 1 (bits 1,2,3,4,6,8)
  python -m compile.quantize --table1           # Table 1 analog
  python -m compile.quantize --bits 4 --steps 400
"""

import argparse
import json

import numpy as np
import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Synthetic GLUE-like tasks
# ---------------------------------------------------------------------------

VOCAB = 32
SEQ = 16


def make_task(name, rng, n):
    """Three tasks of graded difficulty (analogs of GLUE task families)."""
    toks = rng.integers(2, VOCAB, size=(n, SEQ))
    if name == "majority":       # SST-2-like: global evidence pooling
        a = (toks < (2 + (VOCAB - 2) // 2)).sum(axis=1)
        y = (a > SEQ // 2).astype(np.int32)
    elif name == "firstlast":    # MRPC/STS-like: token matching
        y = rng.integers(0, 2, size=n).astype(np.int32)
        toks[:, -1] = np.where(y == 1, toks[:, 0],
                               (toks[:, 0] + 1 - 2) % (VOCAB - 2) + 2)
    elif name == "order":        # RTE/QNLI-like: ordered-pair detection
        y = rng.integers(0, 2, size=n).astype(np.int32)
        pos = rng.integers(0, SEQ - 1, size=n)
        for i in range(n):
            if y[i]:
                toks[i, pos[i]] = 2
                toks[i, pos[i] + 1] = 3
            else:
                toks[i, toks[i] == 2] = 4
    else:
        raise ValueError(name)
    return toks.astype(np.int32), y


TASKS = ["majority", "firstlast", "order"]

# ---------------------------------------------------------------------------
# Tiny transformer with quantization-aware forward
# ---------------------------------------------------------------------------

D, HEADS, LAYERS, FF = 32, 2, 2, 64


def init_params(rng):
    def mat(key, m, n):
        return jax.random.normal(key, (m, n)) * (1.0 / np.sqrt(n))
    keys = jax.random.split(rng, 4 + LAYERS * 8)
    p = {"emb": jax.random.normal(keys[0], (VOCAB, D)) * 0.5,
         "pos": jax.random.normal(keys[1], (SEQ, D)) * 0.1,
         "cls": mat(keys[2], 2, D)}
    k = 3
    for i in range(LAYERS):
        for w, (m, n) in [("wq", (D, D)), ("wk", (D, D)), ("wv", (D, D)),
                          ("wo", (D, D)), ("w1", (FF, D)), ("w2", (D, FF))]:
            p[f"l{i}.{w}"] = mat(keys[k], m, n)
            k += 1
        p[f"l{i}.g1"] = jnp.ones(D)
        p[f"l{i}.b1"] = jnp.zeros(D)
        p[f"l{i}.g2"] = jnp.ones(D)
        p[f"l{i}.b2"] = jnp.zeros(D)
    return p


def ste(x, xq):
    """Straight-through estimator: forward xq, backward identity."""
    return x + jax.lax.stop_gradient(xq - x)


def binarize_w(w):
    """Paper's 1-bit weight quantizer: center, sign, per-tensor scale."""
    c = w - jnp.mean(w)
    alpha = jnp.mean(jnp.abs(c)) + 1e-8
    return ste(w, jnp.sign(c) * alpha)


def quant_act(x, bits, signed=True):
    """Fake-quantize activations to ``bits`` with a dynamic scale (STE)."""
    if bits >= 32:
        return x
    if signed:
        lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
        alpha = jnp.maximum(jnp.std(x) * 2.5, 1e-6) / max(-lo, 1)
    else:
        lo, hi = 0, 2 ** bits - 1
        alpha = jnp.maximum(jnp.max(jax.lax.stop_gradient(x)), 1e-6) / hi
    q = jnp.clip(jnp.round(x / alpha), lo, hi) * alpha
    return ste(x, q)


def forward(p, toks, wbits, abits):
    """Transformer forward; wbits in {1, 32}, abits in {1..8, 32}."""
    qw = binarize_w if wbits == 1 else (lambda w: w)
    qa = (lambda x, signed=True: quant_act(x, abits, signed))
    h = p["emb"][toks] + p["pos"]
    hidden = []
    for i in range(LAYERS):
        x = qa(h)
        q = x @ qw(p[f"l{i}.wq"]).T
        k = x @ qw(p[f"l{i}.wk"]).T
        v = x @ qw(p[f"l{i}.wv"]).T
        dh = D // HEADS
        outs = []
        for hd in range(HEADS):
            sl = slice(hd * dh, (hd + 1) * dh)
            s = qa(q[..., sl]) @ qa(k[..., sl]).swapaxes(-1, -2) / np.sqrt(dh)
            a = jax.nn.softmax(s, axis=-1)
            outs.append(qa(a, signed=False) @ qa(v[..., sl]))
        o = jnp.concatenate(outs, axis=-1) @ qw(p[f"l{i}.wo"]).T
        h = h + o
        mu = h.mean(-1, keepdims=True)
        sd = h.std(-1, keepdims=True) + 1e-5
        h = (h - mu) / sd * p[f"l{i}.g1"] + p[f"l{i}.b1"]
        u = jax.nn.relu(qa(h) @ qw(p[f"l{i}.w1"]).T)
        f = qa(u, signed=False) @ qw(p[f"l{i}.w2"]).T
        h = h + f
        mu = h.mean(-1, keepdims=True)
        sd = h.std(-1, keepdims=True) + 1e-5
        h = (h - mu) / sd * p[f"l{i}.g2"] + p[f"l{i}.b2"]
        hidden.append(h)
    logits = h[:, 0, :] @ p["cls"].T
    return logits, hidden


# ---------------------------------------------------------------------------
# Training (hand-rolled Adam — optax is not available offline)
# ---------------------------------------------------------------------------

def adam_init(p):
    z = jax.tree.map(jnp.zeros_like, p)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, p), "t": 0}


def adam_step(p, g, st, lr=3e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = st["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, st["m"], g)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, st["v"], g)
    mh = jax.tree.map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree.map(lambda v: v / (1 - b2 ** t), v)
    p = jax.tree.map(lambda p, m, v: p - lr * m / (jnp.sqrt(v) + eps), p, mh, vh)
    return p, {"m": m, "v": v, "t": t}


def train(task, wbits, abits, steps, seed=0, teacher=None, log=None):
    rng = np.random.default_rng(seed)
    toks, y = make_task(task, rng, 4096)
    toks_te, y_te = make_task(task, np.random.default_rng(seed + 1), 1024)
    p = init_params(jax.random.PRNGKey(seed))

    def loss_fn(p, tb, yb):
        logits, hidden = forward(p, tb, wbits, abits)
        ce = -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(len(yb)), yb])
        if teacher is not None:
            tl, th = forward(teacher, tb, 32, 32)
            kl = jnp.mean(jnp.sum(
                jax.nn.softmax(tl) *
                (jax.nn.log_softmax(tl) - jax.nn.log_softmax(logits)), -1))
            mse = sum(jnp.mean((a - b) ** 2) for a, b in zip(hidden, th))
            return ce + kl + 0.1 * mse
        return ce

    grad = jax.jit(jax.value_and_grad(loss_fn))
    st = adam_init(p)
    bs = 128
    losses = []
    for it in range(steps):
        idx = rng.integers(0, len(y), bs)
        l, g = grad(p, toks[idx], y[idx])
        p, st = adam_step(p, g, st)
        losses.append(float(l))
        if log and it % 50 == 0:
            log(f"  step {it:4d} loss {float(l):.4f}")
    logits, _ = jax.jit(lambda p, t: forward(p, t, wbits, abits))(p, toks_te)
    acc = float(jnp.mean(jnp.argmax(logits, -1) == y_te))
    return p, acc, losses


def run_sweep(steps, out_path):
    """Fig. 1: accuracy vs activation bits at 1-bit weights (+FP reference)."""
    results = {}
    for task in TASKS:
        print(f"== task {task}")
        teacher, fp_acc, _ = train(task, 32, 32, steps, log=print)
        results.setdefault("fp32", {})[task] = fp_acc
        print(f"  fp32 teacher acc {fp_acc:.3f}")
        for bits in [1, 2, 3, 4, 6, 8]:
            _, acc, _ = train(task, 1, bits, steps, teacher=teacher)
            results.setdefault(f"w1a{bits}", {})[task] = acc
            print(f"  w1a{bits} acc {acc:.3f}")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"\nFig.1 series (avg over {len(TASKS)} tasks):")
    for k, v in results.items():
        print(f"  {k:6s} avg_acc={np.mean(list(v.values())):.3f}")
    return results


def run_table1(steps, out_path):
    """Table 1 analog: per-task accuracy, FP32 vs 1w/4a distilled."""
    rows = {}
    for task in TASKS:
        teacher, fp_acc, _ = train(task, 32, 32, steps)
        _, q_acc, _ = train(task, 1, 4, steps, teacher=teacher)
        rows[task] = {"bert_32_32": fp_acc, "ours_1_4": q_acc}
        print(f"{task:10s} fp32={fp_acc:.3f} ours(1-4)={q_acc:.3f}")
    avg = {k: float(np.mean([r[k] for r in rows.values()]))
           for k in ["bert_32_32", "ours_1_4"]}
    rows["avg"] = avg
    print(f"{'avg':10s} fp32={avg['bert_32_32']:.3f} ours(1-4)={avg['ours_1_4']:.3f}")
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=2)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", action="store_true", help="Fig. 1 bit sweep")
    ap.add_argument("--table1", action="store_true", help="Table 1 analog")
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--out", default="../artifacts/accuracy.json")
    args = ap.parse_args()
    if args.sweep:
        run_sweep(args.steps, args.out)
    elif args.table1:
        run_table1(args.steps, args.out)
    else:
        teacher, fp, _ = train("majority", 32, 32, args.steps, log=print)
        _, q, _ = train("majority", 1, args.bits, args.steps, teacher=teacher)
        print(f"fp32 acc={fp:.3f}  w1a{args.bits} acc={q:.3f}")


if __name__ == "__main__":
    main()
