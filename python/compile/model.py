"""L2: quantized BERT forward pass in JAX (integer-faithful).

The forward pass composes the L1 Pallas kernels (binary-FC, quantized
softmax) plus the ref.py LayerNorm/ReLU semantics into the full encoder
stack of the paper's 1-bit-weight / 4-bit-activation BERT.

Scales are *calibrated per layer and per op* — the paper's "fine-grained,
layerwise quantization": each op's integer rescale factor
``floor(2^12 * s_w * s_x / s_y)`` is chosen from the activation
distribution on a calibration input so the 4-bit output occupies its full
range. Calibrated scales are static Python ints at lowering time (they are
baked into the HLO artifact and shipped to Rust in the weights file).

This module is build-time only: ``aot.py`` lowers ``bert_forward`` once to
HLO text; the Rust runtime executes the artifact as the trusted plaintext
oracle. The MPC protocols in rust/src/protocols/ implement the same
integer pipeline over secret shares.

Weights are synthetic (seeded numpy RNG — the BiT checkpoint is not
reachable offline, see DESIGN.md §Substitutions) but the *semantics* are
exactly the paper's.
"""

import dataclasses
import struct

import numpy as np
import jax.numpy as jnp

from .kernels import ref
from .kernels.binary_matmul import fc_quant_pallas, matmul_quant_pallas
from .kernels.softmax_quant import softmax_quant_pallas

MASK16 = 0xFFFF


@dataclasses.dataclass(frozen=True)
class BertConfig:
    """Model + quantization configuration (mirrors rust/src/model/config.rs)."""
    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    d_ff: int = 3072
    seq_len: int = 32
    n_classes: int = 2
    scale_cls: int = 16
    # softmax input dequantization scale s_x; LN variance scale and eps
    sm_sx: float = 0.5
    ln_sv: float = 4.0
    ln_eps: float = 1.0

    @property
    def d_head(self):
        return self.d_model // self.n_heads


TINY = BertConfig(n_layers=2, d_model=64, n_heads=2, d_ff=128, seq_len=8)
BASE = BertConfig()

# Deterministic parameter order for AOT lowering / the weights artifact.
LAYER_PARAMS = ["wq", "wk", "wv", "wo", "w1", "w2",
                "ln1_g", "ln1_b", "ln2_g", "ln2_b"]
# Per-layer calibrated scale names (scalars, stored in the weights file).
LAYER_SCALES = ["qkv", "att", "av", "o", "f1", "f2", "g1", "g2"]


def param_order(cfg):
    """Flat tensor-parameter list; the .weights.bin artifact uses this order."""
    names = []
    for i in range(cfg.n_layers):
        names.extend(f"layer{i}.{p}" for p in LAYER_PARAMS)
    names.append("cls.w")
    return names


def scale_order(cfg):
    names = []
    for i in range(cfg.n_layers):
        names.extend(f"layer{i}.s_{s}" for s in LAYER_SCALES)
    return names


def gen_weights(cfg, seed=7):
    """Synthetic 1-bit weights + quantized LN params, as a name->array dict."""
    rng = np.random.default_rng(seed)

    def sign(shape):
        return (rng.integers(0, 2, size=shape, dtype=np.int64) * 2 - 1).astype(np.int32)

    w = {}
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        w[p + "wq"] = sign((cfg.d_model, cfg.d_model))
        w[p + "wk"] = sign((cfg.d_model, cfg.d_model))
        w[p + "wv"] = sign((cfg.d_model, cfg.d_model))
        w[p + "wo"] = sign((cfg.d_model, cfg.d_model))
        w[p + "w1"] = sign((cfg.d_ff, cfg.d_model))
        w[p + "w2"] = sign((cfg.d_model, cfg.d_ff))
        w[p + "ln1_g"] = sign((cfg.d_model,))
        w[p + "ln1_b"] = rng.integers(-4, 5, size=(cfg.d_model,)).astype(np.int32)
        w[p + "ln2_g"] = sign((cfg.d_model,))
        w[p + "ln2_b"] = rng.integers(-4, 5, size=(cfg.d_model,)).astype(np.int32)
    w["cls.w"] = sign((cfg.n_classes, cfg.d_model))
    return w


def gen_input(cfg, seed=11):
    """Synthetic quantized embedding input: signed 4-bit [seq, d_model]."""
    rng = np.random.default_rng(seed)
    return rng.integers(-8, 8, size=(cfg.seq_len, cfg.d_model)).astype(np.int32)


# ---------------------------------------------------------------------------
# Scale calibration (the paper's fine-grained layerwise quantization)
# ---------------------------------------------------------------------------

def _pick_scale(acc):
    """Choose scale s.t. trc(scale*acc, 4) spans the signed 4-bit range.

    acc is the raw integer pre-scale accumulator; we target p99(|acc|)
    mapping to ~7 after the >>12, i.e. scale ~= 7*2^12 / p99.
    """
    p99 = float(np.percentile(np.abs(np.asarray(acc, dtype=np.int64)), 99))
    return int(np.clip(round(7 * 4096.0 / max(p99, 1.0)), 1, 4095))


def calibrate(cfg, weights, x4):
    """Run the plaintext forward once in numpy, picking each op's scale."""
    scales = {}
    h = np.asarray(x4, dtype=np.int64)
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        w = {k.split(".", 1)[1]: np.asarray(weights[k], dtype=np.int64)
             for k in weights if k.startswith(p)}

        acc = np.concatenate([h @ w[m].T for m in ("wq", "wk", "wv")])
        s_qkv = _pick_scale(acc)
        scales[p + "s_qkv"] = s_qkv
        q, k_, v = (np.asarray(ref.fc_quant(h, w[m], s_qkv))
                    for m in ("wq", "wk", "wv"))

        dh = cfg.d_head
        heads = [(q[:, j*dh:(j+1)*dh], k_[:, j*dh:(j+1)*dh], v[:, j*dh:(j+1)*dh])
                 for j in range(cfg.n_heads)]
        acc = np.concatenate([qs @ ks.T for qs, ks, _ in heads])
        s_att = _pick_scale(acc)
        scales[p + "s_att"] = s_att
        attns = [np.asarray(ref.softmax_quant(
            jnp.asarray(ref.matmul_quant(qs, ks.T, s_att)), cfg.sm_sx))
            for qs, ks, _ in heads]
        acc = np.concatenate([a.astype(np.int64) @ vs for a, (_, _, vs)
                              in zip(attns, heads)])
        s_av = _pick_scale(acc)
        scales[p + "s_av"] = s_av
        ctx = np.concatenate(
            [np.asarray(ref.matmul_quant(a, vs, s_av))
             for a, (_, _, vs) in zip(attns, heads)], axis=-1)

        acc = ctx.astype(np.int64) @ w["wo"].T
        s_o = _pick_scale(acc)
        scales[p + "s_o"] = s_o
        o4 = np.asarray(ref.fc_quant(ctx, w["wo"], s_o))

        res = h + o4
        scales[p + "s_g1"] = 2048  # u4<<11 >>12 = u4/2: keeps LN output 4-bit
        h = np.asarray(ref.layernorm_quant(jnp.asarray(res), cfg.d_model,
                                           cfg.ln_sv, cfg.ln_eps,
                                           jnp.asarray(weights[p + "ln1_g"]),
                                           2048, jnp.asarray(weights[p + "ln1_b"])))

        acc = h.astype(np.int64) @ w["w1"].T
        s_f1 = _pick_scale(acc)
        scales[p + "s_f1"] = s_f1
        u = np.maximum(np.asarray(ref.fc_quant(h, w["w1"], s_f1)), 0)

        acc = u.astype(np.int64) @ w["w2"].T
        s_f2 = _pick_scale(acc)
        scales[p + "s_f2"] = s_f2
        f = np.asarray(ref.fc_quant(u, w["w2"], s_f2))

        res2 = h + f
        scales[p + "s_g2"] = 2048
        h = np.asarray(ref.layernorm_quant(jnp.asarray(res2), cfg.d_model,
                                           cfg.ln_sv, cfg.ln_eps,
                                           jnp.asarray(weights[p + "ln2_g"]),
                                           2048, jnp.asarray(weights[p + "ln2_b"])))
    return scales


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def attention(cfg, h4, p, s, use_pallas=True):
    """Multi-head self attention over signed 4-bit activations."""
    fc = fc_quant_pallas if use_pallas else ref.fc_quant
    mm = matmul_quant_pallas if use_pallas else ref.matmul_quant
    sm = ((lambda x: softmax_quant_pallas(x, cfg.sm_sx)) if use_pallas
          else (lambda x: ref.softmax_quant(x, cfg.sm_sx)))
    q = fc(h4, p["wq"], s["s_qkv"])
    k = fc(h4, p["wk"], s["s_qkv"])
    v = fc(h4, p["wv"], s["s_qkv"])
    dh = cfg.d_head
    ctx = []
    for hd in range(cfg.n_heads):
        qs, ks, vs = (t[:, hd * dh:(hd + 1) * dh] for t in (q, k, v))
        scores = mm(qs, ks.T, s["s_att"])
        attn = sm(scores)
        ctx.append(mm(attn, vs, s["s_av"]))
    c = jnp.concatenate(ctx, axis=-1)
    return fc(c, p["wo"], s["s_o"])


def encoder_layer(cfg, h4, p, s, use_pallas=True):
    """One transformer encoder layer (attention + FFN, residual + quant LN)."""
    fc = fc_quant_pallas if use_pallas else ref.fc_quant
    o4 = attention(cfg, h4, p, s, use_pallas)
    res = h4 + o4  # 16-bit-ring residual (range ~[-16,14])
    h4 = ref.layernorm_quant(res, cfg.d_model, cfg.ln_sv, cfg.ln_eps,
                             p["ln1_g"], s["s_g1"], p["ln1_b"])
    u = fc(h4, p["w1"], s["s_f1"])
    u = ref.relu_quant(u)
    f = fc(u, p["w2"], s["s_f2"])
    res2 = h4 + f
    return ref.layernorm_quant(res2, cfg.d_model, cfg.ln_sv, cfg.ln_eps,
                               p["ln2_g"], s["s_g2"], p["ln2_b"])


def bert_forward(cfg, x4, flat_weights, scales, use_pallas=True):
    """Full encoder + classifier.

    ``flat_weights`` follows param_order(cfg); ``scales`` is the calibrated
    name->int dict (static). Returns (logits16, h4): signed 16-bit
    classifier logits over the CLS (first) token and the final hidden
    activations (signed 4-bit).
    """
    names = param_order(cfg)
    w = dict(zip(names, flat_weights))
    h = x4
    for i in range(cfg.n_layers):
        pref = f"layer{i}."
        p = {k.split(".", 1)[1]: v for k, v in w.items() if k.startswith(pref)}
        s = {k.split(".", 1)[1]: v for k, v in scales.items()
             if k.startswith(pref)}
        h = encoder_layer(cfg, h, p, s, use_pallas)
    cls_w = (w["cls.w"] * cfg.scale_cls).astype(jnp.int32)
    acc = jnp.matmul(h[0].astype(jnp.int32), cls_w.T) & MASK16
    logits = ref.signed_width(acc, 16)
    return logits, h


# ---------------------------------------------------------------------------
# Weights artifact writer (consumed by rust/src/model/weights.rs)
# ---------------------------------------------------------------------------

MAGIC = b"PPQW"


def write_weights(path, cfg, weights, scales):
    """Binary weights file: MAGIC, header, scale table, tensors in order.

    Layout (little-endian):
      magic[4] | n_layers d_model n_heads d_ff seq_len n_classes (u32 x6)
      | scale_cls (i32) | sm_sx ln_sv ln_eps (f64 x3)
      | n_scales (u32) | per scale: name_len(u32) name value(i32)
      | n_tensors (u32) | per tensor: name_len(u32) name ndim(u32)
        dims(u32*) data(i32*, row-major)
    """
    names = param_order(cfg)
    snames = scale_order(cfg)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<6I", cfg.n_layers, cfg.d_model, cfg.n_heads,
                            cfg.d_ff, cfg.seq_len, cfg.n_classes))
        f.write(struct.pack("<i", cfg.scale_cls))
        f.write(struct.pack("<3d", cfg.sm_sx, cfg.ln_sv, cfg.ln_eps))
        f.write(struct.pack("<I", len(snames)))
        for name in snames:
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<i", int(scales[name])))
        f.write(struct.pack("<I", len(names)))
        for name in names:
            arr = np.ascontiguousarray(weights[name], dtype=np.int32)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes())
