"""AOT compile path: lower the L2 jax model to HLO *text* artifacts.

Run once by ``make artifacts``; the Rust runtime loads the text with
``HloModuleProto::from_text_file`` (the serialized-proto path is broken:
jax >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects —
see /opt/xla-example/README.md).

Artifacts produced (into --out-dir):
  bert_tiny.hlo.txt      full tiny-config forward (weights as parameters)
  bert_tiny.weights.bin  the matching synthetic weights + config header
  bert_tiny.input.bin    the canonical test input
  bert_tiny.expect.bin   expected logits for that input (oracle output)
  fc_quant.hlo.txt       standalone Pallas binary-FC kernel (seq x 64 -> 64)
  softmax_quant.hlo.txt  standalone Pallas quantized-softmax kernel
  MANIFEST.txt           artifact inventory with shapes
"""

import argparse
import functools
import os
import struct

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.binary_matmul import fc_quant_pallas
from .kernels.softmax_quant import softmax_quant_pallas


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    ``as_hlo_text(True)`` = print_large_constants: the default printer
    elides big constants as ``constant({...})`` and the text *parser* on
    the Rust side silently garbles them (lookup tables came back as their
    indices). Full-constant printing round-trips exactly.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def lower_bert(cfg):
    """Lower bert_forward(cfg) with weights as parameters -> HLO text.

    Scales are calibrated first (static ints baked into the HLO and also
    written into the weights artifact for the Rust MPC side).
    """
    names = model.param_order(cfg)
    weights = model.gen_weights(cfg)
    scales = model.calibrate(cfg, weights, model.gen_input(cfg, seed=5))
    specs = [jax.ShapeDtypeStruct(np.asarray(weights[n]).shape, jnp.int32)
             for n in names]
    x_spec = jax.ShapeDtypeStruct((cfg.seq_len, cfg.d_model), jnp.int32)

    def fwd(x4, *flat):
        logits, h = model.bert_forward(cfg, x4, list(flat), scales,
                                       use_pallas=True)
        return logits, h

    lowered = jax.jit(fwd).lower(x_spec, *specs)
    return to_hlo_text(lowered), weights, scales


def write_i32(path, arr):
    arr = np.ascontiguousarray(arr, dtype=np.int32)
    with open(path, "wb") as f:
        f.write(struct.pack("<I", arr.ndim))
        f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
        f.write(arr.tobytes())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    od = args.out_dir
    os.makedirs(od, exist_ok=True)
    manifest = []

    cfg = model.TINY
    hlo, weights, scales = lower_bert(cfg)
    with open(f"{od}/bert_tiny.hlo.txt", "w") as f:
        f.write(hlo)
    model.write_weights(f"{od}/bert_tiny.weights.bin", cfg, weights, scales)
    x4 = model.gen_input(cfg)
    write_i32(f"{od}/bert_tiny.input.bin", x4)
    names = model.param_order(cfg)
    logits, h = model.bert_forward(cfg, jnp.asarray(x4),
                                   [weights[n] for n in names], scales,
                                   use_pallas=False)
    write_i32(f"{od}/bert_tiny.expect.bin", np.asarray(logits))
    write_i32(f"{od}/bert_tiny.hidden.bin", np.asarray(h))
    manifest.append(
        f"bert_tiny.hlo.txt params=x4[{cfg.seq_len},{cfg.d_model}]"
        f"+{len(names)} weight tensors (see weights.bin order)"
        f" -> (logits[{cfg.n_classes}], h[{cfg.seq_len},{cfg.d_model}])")

    # Standalone Pallas kernels (runtime equivalence tests load these).
    seq, d, fc_scale = 8, 64, 64
    fc = functools.partial(fc_quant_pallas, scale=fc_scale)
    low = jax.jit(lambda x, w: (fc(x, w),)).lower(
        jax.ShapeDtypeStruct((seq, d), jnp.int32),
        jax.ShapeDtypeStruct((d, d), jnp.int32))
    with open(f"{od}/fc_quant.hlo.txt", "w") as f:
        f.write(to_hlo_text(low))
    manifest.append(f"fc_quant.hlo.txt x[{seq},{d}] w[{d},{d}] scale={fc_scale}")

    low = jax.jit(
        lambda x: (softmax_quant_pallas(x, cfg.sm_sx),)
    ).lower(jax.ShapeDtypeStruct((seq, seq), jnp.int32))
    with open(f"{od}/softmax_quant.hlo.txt", "w") as f:
        f.write(to_hlo_text(low))
    manifest.append(f"softmax_quant.hlo.txt x[{seq},{seq}] sx={cfg.sm_sx}")

    with open(f"{od}/MANIFEST.txt", "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts to {od}")


if __name__ == "__main__":
    main()
