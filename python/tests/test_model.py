"""L2 model tests: calibration, full forward, pallas==ref, weights artifact."""

import struct

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


@pytest.fixture(scope="module")
def tiny():
    cfg = model.TINY
    w = model.gen_weights(cfg)
    scales = model.calibrate(cfg, w, model.gen_input(cfg, seed=5))
    x = model.gen_input(cfg)
    names = model.param_order(cfg)
    return cfg, w, scales, x, names


def test_calibration_covers_all_scales(tiny):
    cfg, w, scales, x, names = tiny
    assert set(scales.keys()) == set(model.scale_order(cfg))
    assert all(1 <= v <= 4095 for v in scales.values())


def test_forward_shapes(tiny):
    cfg, w, scales, x, names = tiny
    logits, h = model.bert_forward(cfg, jnp.asarray(x), [w[n] for n in names],
                                   scales, use_pallas=False)
    assert logits.shape == (cfg.n_classes,)
    assert h.shape == (cfg.seq_len, cfg.d_model)


def test_forward_pallas_matches_ref(tiny):
    cfg, w, scales, x, names = tiny
    flat = [w[n] for n in names]
    l1, h1 = model.bert_forward(cfg, jnp.asarray(x), flat, scales, use_pallas=False)
    l2, h2 = model.bert_forward(cfg, jnp.asarray(x), flat, scales, use_pallas=True)
    assert (np.asarray(l1) == np.asarray(l2)).all()
    assert (np.asarray(h1) == np.asarray(h2)).all()


def test_hidden_is_4bit_and_alive(tiny):
    cfg, w, scales, x, names = tiny
    _, h = model.bert_forward(cfg, jnp.asarray(x), [w[n] for n in names],
                              scales, use_pallas=False)
    h = np.asarray(h)
    assert h.min() >= -8 and h.max() <= 7
    # calibration must keep the representation alive (not collapsed to ~0)
    assert h.std() > 0.5, h.std()


def test_forward_depends_on_input(tiny):
    cfg, w, scales, x, names = tiny
    flat = [w[n] for n in names]
    _, h1 = model.bert_forward(cfg, jnp.asarray(x), flat, scales, use_pallas=False)
    x2 = model.gen_input(cfg, seed=99)
    _, h2 = model.bert_forward(cfg, jnp.asarray(x2), flat, scales, use_pallas=False)
    diff = (np.asarray(h1) != np.asarray(h2)).mean()
    assert diff > 0.2, f"hidden states nearly input-independent ({diff:.2%})"


def test_param_order_stable(tiny):
    cfg, w, scales, x, names = tiny
    assert names[0] == "layer0.wq"
    assert names[-1] == "cls.w"
    assert len(names) == cfg.n_layers * len(model.LAYER_PARAMS) + 1
    assert set(names) == set(w.keys())


def test_weights_file_roundtrip(tmp_path, tiny):
    cfg, w, scales, x, names = tiny
    path = tmp_path / "w.bin"
    model.write_weights(path, cfg, w, scales)
    with open(path, "rb") as f:
        blob = f.read()
    assert blob[:4] == model.MAGIC
    hdr = struct.unpack_from("<6I", blob, 4)
    assert hdr == (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.d_ff,
                   cfg.seq_len, cfg.n_classes)
    off = 4 + 24 + 4 + 24
    (n_scales,) = struct.unpack_from("<I", blob, off)
    off += 4
    assert n_scales == len(model.scale_order(cfg))
    for name in model.scale_order(cfg):
        (nl,) = struct.unpack_from("<I", blob, off); off += 4
        assert blob[off:off + nl].decode() == name; off += nl
        (v,) = struct.unpack_from("<i", blob, off); off += 4
        assert v == scales[name]
    (n_tensors,) = struct.unpack_from("<I", blob, off)
    off += 4
    assert n_tensors == len(names)
    for name in names:
        (nl,) = struct.unpack_from("<I", blob, off); off += 4
        assert blob[off:off + nl].decode() == name; off += nl
        (nd,) = struct.unpack_from("<I", blob, off); off += 4
        dims = struct.unpack_from(f"<{nd}I", blob, off); off += 4 * nd
        count = int(np.prod(dims))
        data = np.frombuffer(blob, dtype="<i4", count=count, offset=off)
        off += 4 * count
        assert (data.reshape(dims) == np.asarray(w[name])).all()
    assert off == len(blob)


def test_attention_output_range(tiny):
    cfg, w, scales, x, names = tiny
    p = {k.split(".", 1)[1]: v for k, v in w.items() if k.startswith("layer0.")}
    s = {k.split(".", 1)[1]: v for k, v in scales.items()
         if k.startswith("layer0.")}
    out = model.attention(cfg, jnp.asarray(x), p, s, use_pallas=False)
    out = np.asarray(out)
    assert out.shape == (cfg.seq_len, cfg.d_model)
    assert out.min() >= -8 and out.max() <= 7
    assert out.std() > 0.3  # attention signal survives quantization
