"""Put python/ (the directory holding the `compile` package) on the
import path so the tests run from any working directory without an
install step."""

import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
