"""AOT lowering regression tests — pins the two silent HLO-text-path
corruption modes found during bring-up (see DESIGN.md):

  1. ``gather`` ops round-trip as their *indices* through the text parser:
     no artifact may contain a gather (ref.table_lookup is gather-free).
  2. large constants must be printed in full (``print_large_constants``),
     never elided as ``constant({...})``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels.binary_matmul import fc_quant_pallas
from compile.kernels import ref


@pytest.fixture(scope="module")
def fc_hlo():
    low = jax.jit(lambda x, w: (fc_quant_pallas(x, w, 64),)).lower(
        jax.ShapeDtypeStruct((8, 16), jnp.int32),
        jax.ShapeDtypeStruct((16, 16), jnp.int32))
    return aot.to_hlo_text(low)


def test_hlo_text_is_parseable_module(fc_hlo):
    assert fc_hlo.startswith("HloModule")
    assert "ENTRY" in fc_hlo


def test_no_gather_in_kernel_hlo(fc_hlo):
    assert "gather" not in fc_hlo


def test_no_elided_constants(fc_hlo):
    assert "constant({...})" not in fc_hlo


def test_softmax_lowering_has_no_gather():
    from compile.kernels.softmax_quant import softmax_quant_pallas
    low = jax.jit(lambda x: (softmax_quant_pallas(x, 0.5),)).lower(
        jax.ShapeDtypeStruct((4, 8), jnp.int32))
    hlo = aot.to_hlo_text(low)
    assert "gather" not in hlo
    assert "constant({...})" not in hlo
    # the exp/div tables must appear as full constants
    assert hlo.count("constant(") >= 2


def test_table_lookup_matches_indexing():
    table = jnp.asarray(np.arange(100, 116, dtype=np.int32))
    idx = jnp.asarray([0, 5, 15, 3], dtype=jnp.int32)
    got = ref.table_lookup(table, idx)
    assert (np.asarray(got) == np.asarray(table)[np.asarray(idx)]).all()


def test_lower_bert_tiny_artifacts_consistent():
    """lower_bert returns calibrated scales covering scale_order."""
    cfg = model.TINY
    hlo, weights, scales = aot.lower_bert(cfg)
    assert "gather" not in hlo
    assert set(scales.keys()) == set(model.scale_order(cfg))
    assert set(model.param_order(cfg)) == set(weights.keys())
