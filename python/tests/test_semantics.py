"""Property tests on the shared integer semantics (ref.py is the spec)."""

import numpy as np
import jax.numpy as jnp
import pytest

# hypothesis is an optional dev dependency (absent from the offline
# image); skip this module rather than fail collection without it.
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

SETTINGS = dict(max_examples=200, deadline=None)


@given(v=st.integers(-8, 7))
@settings(**SETTINGS)
def test_signed4_roundtrip(v):
    assert int(ref.signed4(v & 0xF)) == v


@given(v=st.integers(0, 2**16 - 1))
@settings(**SETTINGS)
def test_signed_width_16(v):
    s = int(ref.signed_width(np.int64(v), 16))
    assert -(2**15) <= s < 2**15
    assert s % 2**16 == v


@given(a=st.integers(0, 2**16 - 1), b=st.integers(0, 2**16 - 1))
@settings(**SETTINGS)
def test_low_bits_are_ring_hom(a, b):
    """mod-2^4 of a mod-2^16 sum == mod-2^4 sum: why 'num' is local in MPC."""
    assert ((a + b) % 2**16) % 16 == (a % 16 + b % 16) % 16


@given(x=st.integers(-(2**15), 2**15 - 1))
@settings(**SETTINGS)
def test_trc_top_nibble(x):
    """trc(x,4) == floor division by 2^12 in signed arithmetic (no wrap)."""
    got = int(ref.trc16_to4(np.int64(x % 2**16)))
    want = ((x >> 12) + 8) % 16 - 8
    assert got == want


@given(seed=st.integers(0, 2**31), n=st.sampled_from([4, 16, 64]))
@settings(max_examples=100, deadline=None)
def test_ln_mean_exact_spec(seed, n):
    """ln_mean == signed4( floor(2^12/n)*sum mod 2^16 >> 12 ) exactly."""
    rng = np.random.default_rng(seed)
    x = rng.integers(-16, 15, (1, n)).astype(np.int32)
    got = int(np.asarray(ref.ln_mean(jnp.asarray(x), n))[0, 0])
    want = ((((4096 // n) * int(x.sum())) % 2**16 >> 12) + 8) % 16 - 8
    assert got == want


@given(seed=st.integers(0, 2**31), n=st.sampled_from([16, 64]))
@settings(max_examples=50, deadline=None)
def test_ln_mean_approx_centered(seed, n):
    """On centered data (the LN regime) the quantized mean tracks the true
    mean within 2 LSB — means outside [-8,7] wrap by design (paper's
    'clipping is not necessary' remark)."""
    rng = np.random.default_rng(seed)
    x = rng.integers(-8, 8, (1, n)).astype(np.int32)
    got = int(np.asarray(ref.ln_mean(jnp.asarray(x), n))[0, 0])
    true = x.mean()
    assert abs(got - true) <= 2


@given(seed=st.integers(0, 2**31))
@settings(max_examples=50, deadline=None)
def test_relu_quant(seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-8, 8, (16,)).astype(np.int32)
    out = np.asarray(ref.relu_quant(jnp.asarray(x)))
    assert (out == np.maximum(x, 0)).all()


@given(seed=st.integers(0, 2**31))
@settings(max_examples=20, deadline=None)
def test_layernorm_output_range(seed):
    rng = np.random.default_rng(seed)
    n = 64
    x = rng.integers(-16, 15, (2, n)).astype(np.int32)
    g = (rng.integers(0, 2, (n,)) * 2 - 1).astype(np.int32)
    b = rng.integers(-4, 5, (n,)).astype(np.int32)
    out = np.asarray(ref.layernorm_quant(jnp.asarray(x), n, 4.0, 1.0,
                                         jnp.asarray(g), 2048, jnp.asarray(b)))
    assert out.min() >= -8 and out.max() <= 7


def test_ln_div_table_sign_symmetry():
    t = np.asarray(ref.ln_div_table(4.0, 1.0))
    for a in range(-8, 8):
        for v in range(16):
            u_pos = ref.signed4(int(t[(a % 64) * 16 + v]))
            u_neg = ref.signed4(int(t[((-a) % 64) * 16 + v]))
            if -8 < u_pos < 7:  # away from the clip boundary
                assert u_neg == -u_pos or abs(u_neg + u_pos) <= 1
