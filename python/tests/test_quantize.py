"""Smoke tests for the QAT/distillation harness (Fig. 1 / Table 1 driver).

Kept fast: a handful of steps, assert learning happens and the quantizers
behave per spec. The full sweep is run by ``make fig1`` / ``make table1``.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import quantize as qz


def test_tasks_are_balanced_and_learnable():
    rng = np.random.default_rng(0)
    for task in qz.TASKS:
        toks, y = qz.make_task(task, rng, 512)
        assert toks.shape == (512, qz.SEQ)
        assert 0.2 < y.mean() < 0.8, (task, y.mean())


def test_binarize_w_is_sign_times_scale():
    w = jnp.asarray(np.random.default_rng(1).normal(size=(8, 8)).astype(np.float32))
    wq = qz.binarize_w(w)
    c = w - jnp.mean(w)
    alpha = float(jnp.mean(jnp.abs(c)))
    vals = np.unique(np.round(np.abs(np.asarray(wq)), 5))
    assert np.allclose(vals, round(alpha, 5), atol=1e-4)


def test_quant_act_levels():
    x = jnp.linspace(-3, 3, 101)
    for bits in [2, 3, 4]:
        xq = np.asarray(qz.quant_act(x, bits))
        assert len(np.unique(np.round(xq, 5))) <= 2 ** bits


def test_quant_act_identity_at_32():
    x = jnp.linspace(-3, 3, 11)
    assert (np.asarray(qz.quant_act(x, 32)) == np.asarray(x)).all()


def test_fp32_training_learns_majority():
    _, acc, losses = qz.train("majority", 32, 32, steps=120, seed=0)
    assert losses[-1] < losses[0]
    assert acc > 0.75, acc


def test_quantized_training_runs():
    _, acc, losses = qz.train("majority", 1, 4, steps=60, seed=0)
    assert np.isfinite(losses).all()
    assert acc >= 0.45  # must at least not diverge in a short run
