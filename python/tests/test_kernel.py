"""Pallas kernels vs pure-jnp reference — the core L1 correctness signal.

Hypothesis sweeps shapes and values; every case must match ref.py
bit-exactly (the kernels implement identical integer semantics).
"""

import numpy as np
import jax.numpy as jnp
import pytest

# hypothesis is an optional dev dependency (absent from the offline
# image); skip this module rather than fail collection without it.
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.binary_matmul import fc_quant_pallas, matmul_quant_pallas
from compile.kernels.softmax_quant import softmax_quant_pallas

SETTINGS = dict(max_examples=25, deadline=None)


def rng_for(seed):
    return np.random.default_rng(seed)


@given(seed=st.integers(0, 2**31), m=st.sampled_from([1, 2, 4, 8]),
       k=st.sampled_from([8, 16, 64]), n=st.sampled_from([8, 16, 64]),
       scale=st.integers(1, 512))
@settings(**SETTINGS)
def test_fc_quant_matches_ref(seed, m, k, n, scale):
    rng = rng_for(seed)
    x = rng.integers(-8, 8, (m, k)).astype(np.int32)
    w = (rng.integers(0, 2, (n, k)) * 2 - 1).astype(np.int32)
    got = fc_quant_pallas(jnp.asarray(x), jnp.asarray(w), scale,
                          block_m=m, block_n=n)
    want = ref.fc_quant(jnp.asarray(x), jnp.asarray(w), scale)
    assert (np.asarray(got) == np.asarray(want)).all()


@given(seed=st.integers(0, 2**31), m=st.sampled_from([2, 4, 8]),
       k=st.sampled_from([4, 8, 64]), n=st.sampled_from([2, 8, 16]),
       scale=st.integers(1, 512))
@settings(**SETTINGS)
def test_matmul_quant_matches_ref(seed, m, k, n, scale):
    rng = rng_for(seed)
    a = rng.integers(-8, 8, (m, k)).astype(np.int32)
    b = rng.integers(-8, 8, (k, n)).astype(np.int32)
    got = matmul_quant_pallas(jnp.asarray(a), jnp.asarray(b), scale)
    want = ref.matmul_quant(jnp.asarray(a), jnp.asarray(b), scale)
    assert (np.asarray(got) == np.asarray(want)).all()


@given(seed=st.integers(0, 2**31), m=st.sampled_from([1, 4, 8]),
       n=st.sampled_from([4, 8, 16, 32]),
       sx=st.sampled_from([0.125, 0.25, 0.5, 1.0]))
@settings(**SETTINGS)
def test_softmax_quant_matches_ref(seed, m, n, sx):
    rng = rng_for(seed)
    x = rng.integers(-8, 8, (m, n)).astype(np.int32)
    got = softmax_quant_pallas(jnp.asarray(x), sx, block_m=m)
    want = ref.softmax_quant(jnp.asarray(x), sx)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_fc_unsigned_activations():
    """Post-ReLU activations are unsigned 4-bit [0,15]; semantics identical."""
    rng = rng_for(3)
    x = rng.integers(0, 16, (4, 16)).astype(np.int32)
    w = (rng.integers(0, 2, (8, 16)) * 2 - 1).astype(np.int32)
    got = fc_quant_pallas(jnp.asarray(x), jnp.asarray(w), 64,
                          block_m=4, block_n=8)
    want = ref.fc_quant(jnp.asarray(x), jnp.asarray(w), 64)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_fc_output_range():
    rng = rng_for(1)
    x = rng.integers(-8, 8, (8, 64)).astype(np.int32)
    w = (rng.integers(0, 2, (64, 64)) * 2 - 1).astype(np.int32)
    out = np.asarray(fc_quant_pallas(jnp.asarray(x), jnp.asarray(w), 64))
    assert out.min() >= -8 and out.max() <= 7


def test_softmax_output_range_and_monotonicity():
    """Outputs are unsigned 4-bit; the max-score entry gets the max weight."""
    rng = rng_for(2)
    for _ in range(20):
        x = rng.integers(-8, 8, (1, 16)).astype(np.int32)
        out = np.asarray(ref.softmax_quant(jnp.asarray(x), 0.25))[0]
        assert out.min() >= 0 and out.max() <= 15
        assert out[np.argmax(x[0])] == out.max()


def test_exp_table_monotone():
    t = np.asarray(ref.exp_table(0.25))
    vals = [t[d % 16] for d in range(-15, 1)]
    assert vals == sorted(vals)
    assert vals[-1] == 15  # e^0 -> full scale
    assert all(0 <= v <= 15 for v in vals)


def test_div_table_properties():
    t = np.asarray(ref.div_table())
    assert t.min() >= 0 and t.max() <= 15
    # num=0 -> 0 regardless of denominator
    assert all(t[0 * 16 + d] == 0 for d in range(16))
    # fixed denominator: monotone in numerator
    for d in range(16):
        col = [t[n * 16 + d] for n in range(16)]
        assert col == sorted(col)


def test_softmax_quant_vs_float_softmax():
    """Quantized softmax approximates float softmax on peaked scores."""
    rng = rng_for(5)
    sx = 0.5
    errs = []
    for _ in range(50):
        x = rng.integers(-8, 8, (1, 16)).astype(np.int32)
        q = np.asarray(ref.softmax_quant(jnp.asarray(x), sx))[0] / 16.0
        f = np.exp(sx * (x[0] - x[0].max()))
        f = f / f.sum()
        errs.append(np.abs(q - f).max())
    assert np.mean(errs) < 0.15, np.mean(errs)
