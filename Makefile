# Build the python AOT artifacts the Rust runtime/tests consume
# (rust/tests/integration_artifact.rs skips until these exist; running
# them additionally needs `cargo ... --features xla`).
.PHONY: artifacts test bench bench-quick doccheck smoke

artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

test:
	cargo build --release
	cargo test -q
	python3 -m pytest python/tests -q

# Documentation gates (mirrors the CI doc job): rustdoc warnings denied,
# missing_docs denied, and every `DESIGN.md §` citation must name a real
# section.
doccheck:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
	cargo rustc --release --lib -- -D missing-docs
	tools/check_design_citations.sh

# Multi-process deployment smoke: three `repro party` processes on
# localhost, one remote client diffed against the in-process backend,
# then K=4 concurrent clients through the wire-path batcher with an
# in-process bit-exactness check (DESIGN.md §Concurrent serving).
smoke:
	tools/smoke_multiprocess.sh

# CI bench smoke: reduced transport + batching sweeps, recording the
# perf trajectory as JSON-lines ({"bench":…,"wall_ms":…,"bytes":…,
# "rounds":…}) in BENCH_ci.json (uploaded as a CI artifact).
bench-quick:
	rm -f BENCH_ci.json
	cargo bench --bench transport -- --quick --json BENCH_ci.json
	cargo bench --bench batching -- --quick --json BENCH_ci.json
	cargo bench --bench offline -- --quick --json BENCH_ci.json
	cargo bench --bench threads -- --quick --json BENCH_ci.json
	cargo bench --bench buckets -- --quick --json BENCH_ci.json
	cargo bench --bench fleet -- --quick --json BENCH_ci.json
	tools/check_thread_scaling.sh BENCH_ci.json
	@echo "--- BENCH_ci.json"
	@cat BENCH_ci.json

bench:
	cargo bench --bench micro
	cargo bench --bench transport
	cargo bench --bench batching
	cargo bench --bench offline
	cargo bench --bench threads
	cargo bench --bench buckets
	cargo bench --bench fleet
	cargo bench --bench table2
	cargo bench --bench table3
	cargo bench --bench table4
	cargo bench --bench fig5
