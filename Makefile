# Build the python AOT artifacts the Rust runtime/tests consume
# (rust/tests/integration_artifact.rs skips until these exist; running
# them additionally needs `cargo ... --features xla`).
.PHONY: artifacts test bench doccheck smoke

artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

test:
	cargo build --release
	cargo test -q
	python3 -m pytest python/tests -q

# Documentation gates (mirrors the CI doc job): rustdoc warnings denied,
# missing_docs denied, and every `DESIGN.md §` citation must name a real
# section.
doccheck:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
	cargo rustc --release --lib -- -D missing-docs
	tools/check_design_citations.sh

# Multi-process deployment smoke: three `repro party` processes on
# localhost + one remote client, logits diffed against the in-process
# backend (DESIGN.md §Transport backends).
smoke:
	tools/smoke_multiprocess.sh

bench:
	cargo bench --bench micro
	cargo bench --bench transport
	cargo bench --bench batching
	cargo bench --bench offline
	cargo bench --bench table2
	cargo bench --bench table3
	cargo bench --bench table4
	cargo bench --bench fig5
