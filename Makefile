# Build the python AOT artifacts the Rust runtime/tests consume
# (rust/tests/integration_artifact.rs skips until these exist; running
# them additionally needs `cargo ... --features xla`).
.PHONY: artifacts test bench

artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

test:
	cargo build --release
	cargo test -q
	python3 -m pytest python/tests -q

bench:
	cargo bench --bench micro
	cargo bench --bench batching
	cargo bench --bench table2
	cargo bench --bench table3
	cargo bench --bench table4
	cargo bench --bench fig5
